//! Job execution: graph acquisition → cheap init → routing → matching →
//! certification → outcome. Shared by the worker pool and the TCP server.
//!
//! The executor owns the serving-layer context every run gets: a shared
//! [`WorkspacePool`] (scratch buffers reused across jobs), a
//! [`CancelToken`] covering all in-flight runs, the per-job deadline
//! (`MatchJob::timeout` measured from the start of execution, and/or the
//! absolute `MatchJob::deadline` a batch-wide budget sets — the earlier
//! instant wins), and the [`GraphStore`] behind the incremental verbs. A
//! tripped run is a *distinct* failure ([`JobError::DeadlineExceeded`] /
//! [`JobError::Cancelled`]) — never a silently suboptimal answer.
//!
//! Five job ops share the pipeline (see [`JobOp`]): `Match` (one-shot or
//! against a stored graph, warm-started from its cached matching),
//! `Load`/`DropGraph` (store lifecycle), `Update` — apply a
//! [`crate::dynamic::DeltaBatch`] and restore maximality through
//! [`crate::dynamic::repair`], under the same metrics, deadline,
//! cancellation, and certification regime as a match — and `Save`
//! (forced durable snapshot + WAL compaction).
//!
//! With a [`Persistence`] attached (`--data-dir`), the store verbs become
//! durable: a `LOAD` snapshots its base before the graph is visible, a
//! successful `UPDATE` is fsync'd into the per-graph write-ahead log
//! before it is acknowledged (a rolled-back one is never logged),
//! threshold rebuilds piggyback snapshots that compact the log, and
//! `Stored(name)` misses fall through to disk — the transparent-reload
//! half of the `--max-graphs` LRU eviction.

use super::job::{AlgoChoice, GraphSource, JobError, JobOp, MatchJob, MatchOutcome, UpdateStats};
use super::metrics::Metrics;
use super::registry;
use super::router;
use super::store::{CachedMatching, GraphStats, GraphStore, StoreEntry};
use crate::dynamic::{self, DeltaBatch, DynamicGraph};
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{CancelToken, RunCtx, RunOutcome};
use crate::matching::Matching;
use crate::obs::{Level, Obs};
use crate::persist::replicate::{self, AckMode, Event, EventKind, Hub, NodeRole};
use crate::persist::{self, recover, snapshot, wal, Persistence, RecoveryReport};
use crate::runtime::Engine;
use crate::sanitize::lockorder::{self, LockClass};
use crate::trace::{self, JobTrace, TraceBuf, TraceRing};
use crate::util::pool::WorkspacePool;
use crate::util::timer::Timer;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long a quorum-mode write waits for a follower ack before replying
/// `ERR replication` (the write stays locally durable either way).
const DEFAULT_ACK_TIMEOUT: Duration = Duration::from_secs(5);

/// Stateless-per-job executor (cheap to clone across workers; clones share
/// the workspace pool, the cancellation token, the graph store, and —
/// when durability is on — the persistence handle).
#[derive(Clone)]
pub struct Executor {
    pub engine: Option<Arc<Engine>>,
    pub metrics: Arc<Metrics>,
    pool: Arc<WorkspacePool>,
    cancel: CancelToken,
    store: Arc<GraphStore>,
    persist: Option<Arc<Persistence>>,
    max_graphs: Option<usize>,
    /// replication topology role shared with the server's verb handlers
    /// and the follower tailer thread
    role: Arc<NodeRole>,
    /// primary-side frame shipper (idle until a follower subscribes)
    hub: Arc<Hub>,
    ack_mode: AckMode,
    ack_timeout: Duration,
    /// span-trace sink: when set, every job records root spans (and arms
    /// the matcher's phase/kernel spans) and publishes a [`JobTrace`]
    /// here — the `TRACE` verb's source. `None` keeps every
    /// instrumentation site a single is-`None` branch.
    traces: Option<Arc<TraceRing>>,
    /// slow-request log threshold (`--slow-ms`): jobs at or over it get a
    /// compact span summary on stderr and count under `jobs_slow`.
    /// Arms span recording even without a ring.
    slow_threshold: Option<Duration>,
    /// structured event log + flight recorder; `None` keeps every
    /// emission site a single is-`None` branch (embedded `Service` use)
    obs: Option<Arc<Obs>>,
}

/// The effective deadline for a job: `timeout` measured from `start`,
/// capped by the absolute `deadline` when both are set; plus the budget
/// (in ms, as of `start`) reported by a tripped job's error.
fn effective_deadline(job: &MatchJob, start: Instant) -> (Option<Instant>, u64) {
    let from_timeout = job.timeout.map(|b| start + b);
    let deadline = match (from_timeout, job.deadline) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    };
    let budget_ms = deadline
        .map(|d| d.saturating_duration_since(start).as_millis() as u64)
        .unwrap_or(0);
    (deadline, budget_ms)
}

impl Executor {
    pub fn new(engine: Option<Arc<Engine>>, metrics: Arc<Metrics>) -> Self {
        Self {
            engine,
            metrics,
            pool: Arc::new(WorkspacePool::new()),
            cancel: CancelToken::new(),
            store: Arc::new(GraphStore::new()),
            persist: None,
            max_graphs: None,
            role: Arc::new(NodeRole::new()),
            hub: Arc::new(Hub::new()),
            ack_mode: AckMode::Local,
            ack_timeout: DEFAULT_ACK_TIMEOUT,
            traces: None,
            slow_threshold: None,
            obs: None,
        }
    }

    /// Attach the structured event log / flight recorder. Lifecycle
    /// events (eviction, recovery, promotion, quorum timeouts, WAL
    /// compaction, slow requests) are emitted through it from here on.
    pub fn with_obs(mut self, obs: Arc<Obs>) -> Self {
        self.obs = Some(obs);
        self
    }

    /// The event log, if one is attached.
    pub fn obs(&self) -> Option<&Arc<Obs>> {
        self.obs.as_ref()
    }

    /// Arm span tracing: every job from here on records root/phase/kernel
    /// spans and publishes a [`JobTrace`] into `ring` (what the `TRACE`
    /// verb serves). Attach before cloning across workers.
    pub fn with_trace_ring(mut self, ring: Arc<TraceRing>) -> Self {
        self.traces = Some(ring);
        self
    }

    /// Log jobs that take `threshold` or longer as a `slow_job` event
    /// (warn level, compact per-span breakdown), counting them under
    /// `jobs_slow`. Implies span recording (a slow job's trace exists to
    /// be summarized).
    pub fn with_slow_threshold(mut self, threshold: Duration) -> Self {
        self.slow_threshold = Some(threshold);
        self
    }

    /// The trace ring, if tracing is armed.
    pub fn trace_ring(&self) -> Option<&Arc<TraceRing>> {
        self.traces.as_ref()
    }

    /// A fresh per-job span buffer, or `None` when tracing is disarmed.
    /// The timebase is backdated to `job.submitted_at` (when the service
    /// stamped one) so the queue wait opens the timeline as its own span.
    fn trace_buf(&self, job: &MatchJob) -> Option<Box<TraceBuf>> {
        if self.traces.is_none() && self.slow_threshold.is_none() {
            return None;
        }
        Some(match job.submitted_at {
            Some(t0) => {
                let mut b = TraceBuf::with_origin(t0);
                b.host_span("queue_wait", "job", 0, vec![]);
                b
            }
            None => TraceBuf::new(),
        })
    }

    /// Seal a job's trace: build the [`JobTrace`], emit the slow-request
    /// log line when the job crossed the threshold, and publish to the
    /// ring. `solve` carries `(kernel launches, modeled device cycles)`
    /// from the run's `RunStats` (zeros when the op never solved).
    fn seal_trace(
        &self,
        buf: Option<Box<TraceBuf>>,
        job: &MatchJob,
        op: &'static str,
        out: &MatchOutcome,
        solve: (u64, u64),
        total_secs: f64,
    ) {
        let Some(buf) = buf else { return };
        let slow = self
            .slow_threshold
            .is_some_and(|t| total_secs >= t.as_secs_f64());
        if self.traces.is_none() && !slow {
            return; // armed only for the slow log, and this job was fast
        }
        let graph = match (&job.op, &job.source) {
            (JobOp::Load { name }, _)
            | (JobOp::Update { name, .. }, _)
            | (JobOp::DropGraph { name }, _)
            | (JobOp::Save { name }, _) => Some(name.clone()),
            (JobOp::Match, GraphSource::Stored(name)) => Some(name.clone()),
            (JobOp::Match, _) => None,
        };
        let total_us = (total_secs * 1e6) as u64;
        let (spans, dropped_spans) = buf.into_spans();
        let t = JobTrace {
            job_id: job.id,
            op,
            graph,
            algo: out.algo.clone(),
            start_unix_ms: trace::unix_ms().saturating_sub(total_us / 1000),
            total_us,
            ok: out.error.is_none(),
            error: out.error.as_ref().map(|e| e.to_string()),
            phases: out.phases,
            launches: solve.0,
            device_cycles: solve.1,
            device_parallel_cycles: out.device_parallel_cycles,
            shards: out.shards,
            exchange_words: out.exchange_words,
            cardinality: out.cardinality as u64,
            spans,
            dropped_spans,
        };
        if let Some(obs) = &self.obs {
            // every traced job leaves a one-line span summary in the
            // flight recorder (debug level: the ring always records it,
            // the sinks only under --log-level debug)
            obs.event(Level::Debug, "job")
                .field_u64("job", t.job_id)
                .field("op", t.op)
                .field("graph", t.graph.as_deref().unwrap_or("-"))
                .field("algo", if t.algo.is_empty() { "-" } else { &t.algo })
                .field_f64("total_ms", total_secs * 1e3)
                .field("outcome", out.error.as_ref().map(JobError::kind).unwrap_or("complete"))
                .field("spans", &t.summary())
                .emit();
        }
        if slow {
            self.metrics.jobs_slow.fetch_add(1, Ordering::Relaxed);
            if let Some(obs) = &self.obs {
                // the outcome rides along so a slow *failed* job (timeout,
                // cancellation, a rolled-back update) is distinguishable
                // from a slow success in the log stream
                let outcome =
                    out.error.as_ref().map(JobError::kind).unwrap_or("complete");
                let mut ev = obs
                    .event(Level::Warn, "slow_job")
                    .field_u64("job", t.job_id)
                    .field("op", t.op)
                    .field("graph", t.graph.as_deref().unwrap_or("-"))
                    .field("algo", if t.algo.is_empty() { "-" } else { &t.algo })
                    .field_f64("total_ms", total_secs * 1e3)
                    .field("outcome", outcome)
                    .field("spans", &t.summary());
                if t.op == "update" && out.error.is_some() {
                    // every failed update rolls the stored graph back
                    ev = ev.field_bool("rolled_back", true);
                }
                ev.emit();
            }
        }
        if let Some(ring) = &self.traces {
            ring.publish(t);
        }
    }

    /// The full Prometheus exposition the `METRICS` verb serves: every
    /// process-wide counter/gauge/histogram plus the per-spec families
    /// (from [`Metrics::prometheus`]), extended with per-graph serving
    /// families from the store's [`GraphStats`].
    pub fn prometheus(&self) -> String {
        let mut s = self.metrics.prometheus();
        // build identity as a constant-1 info gauge (the standard
        // Prometheus idiom): scrapes can join version/revision/role onto
        // any other family without parsing STATS
        s.push_str(&format!(
            "# HELP bimatch_build_info build and role identity (constant 1)\n\
             # TYPE bimatch_build_info gauge\n\
             bimatch_build_info{{version=\"{}\",git=\"{}\",role=\"{}\"}} 1\n",
            env!("CARGO_PKG_VERSION"),
            env!("BIMATCH_GIT_HASH"),
            self.role_name(),
        ));
        s.push_str(&format!(
            "# HELP bimatch_node_epoch this node's fencing epoch\n\
             # TYPE bimatch_node_epoch gauge\nbimatch_node_epoch {}\n",
            self.role.epoch()
        ));
        let graphs = self.store.all_graph_stats();
        if graphs.is_empty() {
            return s;
        }
        type Get = fn(&GraphStats) -> u64;
        let families: [(&str, &str, Get); 6] = [
            ("bimatch_graph_matches_total", "MATCH jobs served per stored graph", |g| g.matches),
            (
                "bimatch_graph_recomputes_total",
                "stored-graph matches solved from scratch (cold or stale cache)",
                |g| g.recomputes,
            ),
            ("bimatch_graph_updates_total", "UPDATE batches committed per stored graph", |g| {
                g.updates
            }),
            ("bimatch_graph_repairs_total", "incremental repairs run per stored graph", |g| {
                g.repairs
            }),
            ("bimatch_graph_wal_appends_total", "WAL frames fsync'd per stored graph", |g| {
                g.wal_appends
            }),
            ("bimatch_graph_snapshots_total", "snapshot files written per stored graph", |g| {
                g.snapshots
            }),
        ];
        for (name, help, get) in families {
            s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
            for (graph, stats) in &graphs {
                s.push_str(&format!(
                    "{name}{{graph=\"{}\"}} {}\n",
                    super::metrics::prom_label_escape(graph),
                    get(stats)
                ));
            }
        }
        s
    }

    /// Attach a durability layer (`--data-dir`): from here on, `LOAD`s
    /// snapshot their base, successful `UPDATE`s hit the write-ahead log
    /// (fsync'd) before they are acknowledged, threshold rebuilds
    /// piggyback snapshots, and `DROP`s delete the on-disk state. Attach
    /// *before* cloning the executor across workers. Also restores the
    /// node's fencing epoch from `<data-dir>/epoch`.
    pub fn with_persistence(mut self, persist: Arc<Persistence>) -> Self {
        self.role
            .epoch
            .store(replicate::read_epoch(persist.dir()), Ordering::Relaxed);
        self.persist = Some(persist);
        self
    }

    /// Set how writes are acknowledged (`--ack-mode`): `Local` replies on
    /// the local fsync; `Quorum` additionally blocks until a follower
    /// acks the replicated event.
    pub fn with_ack_mode(mut self, mode: AckMode) -> Self {
        self.ack_mode = mode;
        self
    }

    /// Override the quorum ack wait (tests use a short one).
    pub fn with_ack_timeout(mut self, timeout: Duration) -> Self {
        self.ack_timeout = timeout;
        self
    }

    pub fn ack_mode(&self) -> AckMode {
        self.ack_mode
    }

    /// The replication role shared with the server and tailer.
    pub fn role(&self) -> &Arc<NodeRole> {
        &self.role
    }

    /// The role as its wire name (the `LAG`/`HEALTH` vocabulary):
    /// `fenced` > `follower` > `primary`.
    pub fn role_name(&self) -> &'static str {
        if self.role.fenced.load(std::sync::atomic::Ordering::Relaxed) {
            "fenced"
        } else if self.role.is_replica() {
            "follower"
        } else {
            "primary"
        }
    }

    /// The primary-side frame shipper.
    pub fn hub(&self) -> &Arc<Hub> {
        &self.hub
    }

    /// Flip replica mode: a read-only node rejects every write verb with
    /// [`JobError::ReadOnly`] while still serving `MATCH` from the
    /// replicated state.
    pub fn set_read_only(&self, read_only: bool) {
        self.role.read_only.store(read_only, Ordering::Relaxed);
    }

    /// Cap the in-memory store at `max` graphs (LRU): a `LOAD` beyond the
    /// cap evicts the stalest graph — snapshotting it first when
    /// persistence is on, so a later `MATCH name=` transparently reloads
    /// it from disk. Without persistence, eviction discards the graph.
    pub fn with_max_graphs(mut self, max: usize) -> Self {
        self.max_graphs = Some(max);
        self
    }

    /// The durability layer, if one is attached.
    pub fn persistence(&self) -> Option<&Arc<Persistence>> {
        self.persist.as_ref()
    }

    /// Startup recovery: scan the data dir, install every recoverable
    /// graph (WAL tail replayed, matching restored by seeded repair), and
    /// fence the version allocator past everything on disk. A no-op
    /// (empty report) without persistence. Run before accepting traffic.
    pub fn recover(&self) -> std::io::Result<RecoveryReport> {
        let Some(p) = &self.persist else {
            return Ok(RecoveryReport::default());
        };
        let report =
            recover::recover_into(p, &self.store, &self.metrics, self.engine.clone(), &self.pool)?;
        if let Some(cap) = self.max_graphs {
            // recovery may have resurrected more graphs than the cap
            while self.store.len() > cap {
                let Some(victim) = self.store.lru_victim("") else { break };
                if !self.evict_graph(&victim) {
                    break;
                }
            }
        }
        if let Some(obs) = &self.obs {
            let mut ev = obs
                .event(Level::Info, "recovery")
                .field_u64("recovered", report.recovered() as u64)
                .field_u64("skipped", report.skipped.len() as u64);
            if !report.skipped.is_empty() {
                ev = ev.field("skipped_names", &report.skipped.join(","));
            }
            ev.emit();
        }
        Ok(report)
    }

    /// Push `name` out of memory. With persistence, its live state is
    /// snapshotted (and the WAL compacted) first so nothing is lost and a
    /// later `MATCH name=` reloads it transparently; a snapshot failure
    /// vetoes the eviction (memory pressure never wins over durability).
    /// Returns whether the graph left memory.
    fn evict_graph(&self, name: &str) -> bool {
        let Some(entry) = self.store.entry(name) else {
            return true; // already gone
        };
        let mut e = lockorder::lock(LockClass::Entry, &entry);
        let mut version = 0;
        let snapshotted = self.persist.is_some();
        if let Some(p) = &self.persist {
            let g = e.graph.snapshot();
            version = e.graph.version();
            let matching = e
                .matching
                .as_ref()
                .filter(|c| c.version == version)
                .map(|c| c.matching.clone());
            if p.record_snapshot(name, &g, version, matching.as_ref()).is_err() {
                if let Some(obs) = &self.obs {
                    obs.event(Level::Warn, "evict_vetoed")
                        .field("graph", name)
                        .field_u64("version", version)
                        .emit();
                }
                return false;
            }
            self.metrics.snapshots_written.fetch_add(1, Ordering::Relaxed);
        }
        // remove from the map while still holding the entry lock, so a
        // racing UPDATE on this graph either committed before us or will
        // observe itself unmapped
        self.store.drop_graph(name);
        drop(e);
        self.metrics.graphs_evicted.fetch_add(1, Ordering::Relaxed);
        if let Some(obs) = &self.obs {
            obs.event(Level::Info, "graph_evicted")
                .field("graph", name)
                .field_u64("version", version)
                .field_bool("snapshotted", snapshotted)
                .emit();
        }
        true
    }

    /// After installing `keep`, evict LRU graphs until the cap holds.
    fn enforce_graph_cap(&self, keep: &str) {
        let Some(cap) = self.max_graphs else { return };
        while self.store.len() > cap {
            let Some(victim) = self.store.lru_victim(keep) else { break };
            if !self.evict_graph(&victim) {
                break;
            }
        }
    }

    /// A `Stored(name)` miss falls through to disk: recover the single
    /// graph (snapshot + WAL tail + seeded repair of its matching) and
    /// install it — the transparent-reload half of LRU eviction. Counts
    /// under `graphs_recovered`. The persistence name lock is held across
    /// recover + install (with a re-check under it) so the reload can
    /// neither resurrect a concurrently `DROP`ped graph nor overwrite a
    /// fresh re-`LOAD`'s incarnation with stale disk state — both of
    /// those serialize on the same lock before touching the files or the
    /// map.
    fn reload_from_disk(&self, name: &str) -> Option<Arc<std::sync::Mutex<StoreEntry>>> {
        let p = self.persist.as_ref()?;
        let lock = p.name_lock(name);
        let held = lockorder::lock(LockClass::Name, &lock);
        // re-check under the lock: a racing LOAD or reload may have
        // installed the graph while we waited
        if let Some(entry) = self.store.entry(name) {
            return Some(entry);
        }
        let rec = p.recover_graph_locked(name).ok()??;
        recover::install_recovered(rec, &self.store, &self.metrics, self.engine.clone(), &self.pool);
        if let Some(obs) = &self.obs {
            obs.event(Level::Info, "graph_reloaded").field("graph", name).emit();
        }
        // the cap sweep happens after releasing the name lock: eviction
        // snapshots the victim under the *victim's* name lock, and two
        // reloads evicting each other's graphs must not hold both locks
        drop(held);
        self.enforce_graph_cap(name);
        self.store.entry(name)
    }

    /// The shared scratch-buffer pool (observability + tests).
    pub fn workspace_pool(&self) -> &Arc<WorkspacePool> {
        &self.pool
    }

    /// The graph store behind `LOAD`/`UPDATE`/`MATCH name=…`/`DROP`,
    /// shared by every clone of this executor.
    pub fn store(&self) -> &Arc<GraphStore> {
        &self.store
    }

    /// Token cancelling every in-flight and future run of this executor
    /// (and its clones) at the next inter-phase checkpoint.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    fn acquire(&self, source: &GraphSource) -> Result<Arc<BipartiteCsr>, String> {
        match source {
            GraphSource::Generate { family, n, seed, permute } => {
                let g = family.generate(*n, *seed);
                Ok(Arc::new(if *permute {
                    crate::graph::random_permute(&g, seed.wrapping_add(0x5EED))
                } else {
                    g
                }))
            }
            GraphSource::MtxFile(path) => crate::graph::mtx::read_mtx(std::path::Path::new(path))
                .map(Arc::new)
                .map_err(|e| format!("reading {path}: {e}")),
            GraphSource::InMemory(g) => Ok(g.clone()),
            GraphSource::Stored(name) => {
                Err(format!("no stored graph named {name:?} here — use MATCH name=… paths"))
            }
        }
    }

    fn blank(job_id: u64) -> MatchOutcome {
        MatchOutcome {
            job_id,
            algo: String::new(),
            nr: 0,
            nc: 0,
            n_edges: 0,
            cardinality: 0,
            init_cardinality: 0,
            certified: false,
            t_load: 0.0,
            t_init: 0.0,
            t_match: 0.0,
            phases: 0,
            frontier_peak: 0,
            endpoints_total: 0,
            device_parallel_cycles: 0,
            shards: 0,
            exchange_words: 0,
            exchange_steps: 0,
            update: None,
            error: None,
        }
    }

    fn fail(&self, out: &mut MatchOutcome, err: JobError) {
        out.error = Some(err);
        self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }

    fn resolve_spec(&self, job: &MatchJob, g: &BipartiteCsr) -> super::spec::AlgoSpec {
        let mut spec = match &job.algo {
            AlgoChoice::Auto => router::route_graph(g),
            AlgoChoice::Spec(s) => *s,
        };
        // frontier override as a typed field edit, applied *after* routing:
        // a GPU pick (named or auto-routed) gets the requested mode while
        // CPU-routed graphs keep their pfp/dfs pick — so `--frontier
        // fullscan` forces the paper-faithful variant only where a GPU
        // algorithm actually runs
        if let Some(fm) = job.frontier {
            spec.set_frontier(fm);
        }
        spec
    }

    pub fn execute(&self, job: &MatchJob) -> MatchOutcome {
        // the read-replica contract: reads flow, writes bounce with a
        // typed error — a fenced ex-primary behaves the same way
        if !matches!(job.op, JobOp::Match) && !self.role.is_writable() {
            let mut out = Self::blank(job.id);
            self.fail(&mut out, JobError::ReadOnly);
            return out;
        }
        match &job.op {
            JobOp::Match => self.execute_match(job),
            JobOp::Load { name } => self.execute_load(job, name),
            JobOp::Update { name, batch } => self.execute_update(job, name, batch),
            JobOp::DropGraph { name } => self.execute_drop(job, name),
            JobOp::Save { name } => self.execute_save(job, name),
        }
    }

    /// Whether write verbs should publish replication events: there is a
    /// live follower, or quorum mode demands one (publishing then lets
    /// the quorum wait fail honestly instead of silently passing).
    fn replicating(&self) -> bool {
        self.hub.subscriber_count() > 0 || self.ack_mode == AckMode::Quorum
    }

    fn publish_event(&self, kind: EventKind, name: &str, data: Vec<u8>) -> u64 {
        let seq = self.hub.publish(kind, name, data);
        self.metrics.repl_frames_shipped.fetch_add(1, Ordering::Relaxed);
        self.metrics.repl_lag.store(self.hub.lag(), Ordering::Relaxed);
        seq
    }

    /// The quorum write barrier: under `--ack-mode quorum`, block until a
    /// follower acked `seq`. On timeout the job fails with
    /// `JobError::Replication` — the write is already locally durable
    /// (never rolled back here), so the client must treat it as
    /// in-doubt, exactly like a commit whose ack was lost on the wire.
    /// Returns whether the job was failed.
    fn wait_quorum(&self, seq: Option<u64>, out: &mut MatchOutcome) -> bool {
        let Some(seq) = seq else { return false };
        if self.ack_mode != AckMode::Quorum {
            return false;
        }
        if self.hub.wait_acked(seq, self.ack_timeout) {
            self.metrics.repl_lag.store(self.hub.lag(), Ordering::Relaxed);
            return false;
        }
        if let Some(obs) = &self.obs {
            obs.event(Level::Warn, "quorum_timeout")
                .field_u64("seq", seq)
                .field_u64("timeout_ms", self.ack_timeout.as_millis() as u64)
                .field_u64("followers", self.hub.subscriber_count() as u64)
                .emit();
        }
        self.fail(
            out,
            JobError::Replication(format!(
                "no follower acknowledged seq {seq} within {} ms; \
                 the write is durable locally but unconfirmed",
                self.ack_timeout.as_millis()
            )),
        );
        true
    }

    fn execute_match(&self, job: &MatchJob) -> MatchOutcome {
        let total = Timer::start();
        // the deadline covers the whole job: load + init + matching
        let (deadline, budget_ms) = effective_deadline(job, Instant::now());
        let mut out = Self::blank(job.id);
        let mut tbuf = self.trace_buf(job);
        let load_mark = tbuf.as_ref().map(|b| b.now_us());
        // acquisition; a stored graph also brings its entry handle,
        // version, and cached matching (the warm start that makes repeat
        // MATCHes one quiet phase) — the handle is kept so the write-back
        // below targets exactly the incarnation this snapshot came from
        let mut stored: Option<(Arc<std::sync::Mutex<StoreEntry>>, u64)> = None;
        let mut warm: Option<Matching> = None;
        let g = match &job.source {
            GraphSource::Stored(name) => {
                // a miss falls through to disk before failing: an LRU-
                // evicted (or crash-surviving) graph reloads transparently
                let view = self.store.graph_for_match(name).or_else(|| {
                    self.reload_from_disk(name)?;
                    self.store.graph_for_match(name)
                });
                match view {
                    Some(view) => {
                        warm = view.cached.map(|c| c.matching);
                        stored = Some((view.entry, view.version));
                        view.graph
                    }
                    None => {
                        self.fail(
                            &mut out,
                            JobError::Load(format!(
                                "no stored graph named {name:?} (LOAD it first)"
                            )),
                        );
                        return out;
                    }
                }
            }
            other => match self.acquire(other) {
                Ok(g) => g,
                Err(e) => {
                    self.fail(&mut out, JobError::Load(e));
                    return out;
                }
            },
        };
        out.t_load = total.elapsed_secs();
        out.nr = g.nr;
        out.nc = g.nc;
        out.n_edges = g.n_edges();
        if let (Some(b), Some(m)) = (tbuf.as_mut(), load_mark) {
            b.host_span(
                "load",
                "job",
                m,
                vec![("nr", g.nr as u64), ("nc", g.nc as u64), ("edges", g.n_edges() as u64)],
            );
        }

        let t_init = Timer::start();
        let init_mark = tbuf.as_ref().map(|b| b.now_us());
        // the store guards versions, but sizes are re-checked here at
        // the trust boundary rather than assumed; whether the warm start
        // was actually usable feeds the per-graph repair-vs-recompute split
        let (init, warm_used) = match warm {
            Some(m) if m.nr() == g.nr && m.nc() == g.nc => (m, true),
            _ => (job.init.run(&g), false),
        };
        out.t_init = t_init.elapsed_secs();
        out.init_cardinality = init.cardinality();
        if let (Some(b), Some(m)) = (tbuf.as_mut(), init_mark) {
            b.host_span("init", "job", m, vec![("cardinality", out.init_cardinality as u64)]);
        }

        let spec = self.resolve_spec(job, &g);
        out.algo = spec.to_string();
        let Some(algo) = registry::build(&spec, self.engine.clone()) else {
            self.fail(&mut out, JobError::Unavailable(registry::unavailable_msg(&spec)));
            return out;
        };
        out.algo = algo.name();

        let mut ctx = RunCtx::new(self.pool.clone()).with_cancel(self.cancel.clone());
        ctx.set_deadline(deadline);
        let solve_mark = tbuf.as_ref().map(|b| b.now_us());
        if let Some(b) = tbuf.take() {
            ctx.arm_trace(b); // matcher phase + kernel spans go here
        }
        let t_match = Timer::start();
        let result = algo.run(&g, init, &mut ctx);
        out.t_match = t_match.elapsed_secs();
        tbuf = ctx.take_trace();
        out.cardinality = result.matching.cardinality();
        out.phases = result.stats.phases;
        out.frontier_peak = result.stats.frontier_peak;
        out.endpoints_total = result.stats.endpoints_total;
        out.device_parallel_cycles = result.stats.device_parallel_cycles;
        out.shards = result.stats.shards;
        out.exchange_words = result.stats.exchange_words;
        out.exchange_steps = result.stats.exchange_steps;
        let solve_detail = (
            result.stats.launches_per_phase.iter().map(|&l| l as u64).sum::<u64>(),
            result.stats.device_cycles,
        );
        if let (Some(b), Some(m)) = (tbuf.as_mut(), solve_mark) {
            b.host_span(
                "solve",
                "job",
                m,
                vec![
                    ("phases", result.stats.phases),
                    ("launches", solve_detail.0),
                    ("device_cycles", solve_detail.1),
                ],
            );
        }

        match result.outcome {
            RunOutcome::Complete => {}
            RunOutcome::DeadlineExceeded => {
                self.metrics.jobs_timed_out.fetch_add(1, Ordering::Relaxed);
                self.fail(&mut out, JobError::DeadlineExceeded { timeout_ms: budget_ms });
                self.metrics.record_spec(&out.algo, total.elapsed_secs(), false, solve_detail.1);
                self.seal_trace(tbuf, job, "match", &out, solve_detail, total.elapsed_secs());
                return out;
            }
            RunOutcome::Cancelled => {
                self.metrics.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                self.fail(&mut out, JobError::Cancelled);
                self.metrics.record_spec(&out.algo, total.elapsed_secs(), false, solve_detail.1);
                self.seal_trace(tbuf, job, "match", &out, solve_detail, total.elapsed_secs());
                return out;
            }
        }

        if job.certify {
            let cert_mark = tbuf.as_ref().map(|b| b.now_us());
            match result.matching.certify(&g) {
                Ok(()) => {
                    out.certified = true;
                    if let (Some(b), Some(m)) = (tbuf.as_mut(), cert_mark) {
                        b.host_span("certify", "job", m, vec![]);
                    }
                }
                Err(e) => {
                    // a job whose result fails certification is a *failed*
                    // job: it must not count as completed nor contribute
                    // its (untrusted) cardinality to matched_total, so
                    // `submitted == completed + failed` stays an invariant
                    self.metrics.certify_failures.fetch_add(1, Ordering::Relaxed);
                    self.fail(&mut out, JobError::Certify(e));
                    self.metrics.record_spec(&out.algo, total.elapsed_secs(), false, solve_detail.1);
                    self.seal_trace(tbuf, job, "match", &out, solve_detail, total.elapsed_secs());
                    return out;
                }
            }
        }

        // a successful stored-graph match becomes the new cache, written
        // through the entry handle captured at read time (see
        // `GraphStore::cache_into` for why never by name). A concurrent
        // UPDATE moves the version and wins (its repair is newer); the
        // matching is moved, not cloned (nothing reads it past this
        // point).
        if let Some((entry, version)) = stored {
            GraphStore::cache_into(&entry, result.matching, version);
            // per-graph serving stats: how often this graph is matched,
            // and how often the cached matching was unusable (recompute)
            let mut e = lockorder::lock(LockClass::Entry, &entry);
            e.stats.matches += 1;
            if !warm_used {
                e.stats.recomputes += 1;
            }
        }

        self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .edges_processed
            .fetch_add(out.n_edges as u64, Ordering::Relaxed);
        self.metrics
            .matched_total
            .fetch_add(out.cardinality as u64, Ordering::Relaxed);
        self.metrics.observe_latency(total.elapsed_secs());
        self.metrics.record_spec(&out.algo, total.elapsed_secs(), true, solve_detail.1);
        self.seal_trace(tbuf, job, "match", &out, solve_detail, total.elapsed_secs());
        out
    }

    fn execute_load(&self, job: &MatchJob, name: &str) -> MatchOutcome {
        let total = Timer::start();
        let mut out = Self::blank(job.id);
        let mut tbuf = self.trace_buf(job);
        if matches!(job.source, GraphSource::Stored(_)) {
            self.fail(
                &mut out,
                JobError::Load("LOAD needs a concrete graph source (family/n or mtx)".into()),
            );
            return out;
        }
        let load_mark = tbuf.as_ref().map(|b| b.now_us());
        let g = match self.acquire(&job.source) {
            Ok(g) => g,
            Err(e) => {
                self.fail(&mut out, JobError::Load(e));
                return out;
            }
        };
        out.t_load = total.elapsed_secs();
        out.nr = g.nr;
        out.nc = g.nc;
        out.n_edges = g.n_edges();
        if let (Some(b), Some(m)) = (tbuf.as_mut(), load_mark) {
            b.host_span(
                "load",
                "job",
                m,
                vec![("nr", g.nr as u64), ("nc", g.nc as u64), ("edges", g.n_edges() as u64)],
            );
        }
        // durability before visibility: the base snapshot + WAL reset hit
        // disk first, so a LOAD the client saw acknowledged can always be
        // recovered — and a persist failure rejects the LOAD outright
        // rather than leaving a graph that would silently vanish on crash.
        // The name lock spans persist + install, so a concurrent DROP or
        // reload serializes around the whole LOAD instead of interleaving
        // between its disk and map halves.
        let base = self.store.allocate_version_base();
        let name_lock = self.persist.as_ref().map(|p| p.name_lock(name));
        let name_guard = name_lock.as_ref().map(|l| lockorder::lock(LockClass::Name, l));
        if let Some(p) = &self.persist {
            let snap_mark = tbuf.as_ref().map(|b| b.now_us());
            if let Err(e) = p.record_load_locked(name, &g, base) {
                self.fail(&mut out, JobError::Load(format!("persisting LOAD failed: {e}")));
                return out;
            }
            self.metrics.snapshots_written.fetch_add(1, Ordering::Relaxed);
            self.metrics.wal_appends.fetch_add(1, Ordering::Relaxed);
            if let (Some(b), Some(m)) = (tbuf.as_mut(), snap_mark) {
                b.host_span("snapshot_write", "persist", m, vec![]);
            }
        }
        // ship the new incarnation as a snapshot event while still under
        // the name lock, so followers see the re-base strictly before any
        // of its update frames
        let mut repl_seq = None;
        if self.replicating() {
            let data = snapshot::encode_snapshot(base, &g, None);
            repl_seq = Some(self.publish_event(EventKind::Snap, name, data));
        }
        self.store.load_with_base(name, g, base);
        drop(name_guard);
        drop(name_lock);
        self.enforce_graph_cap(name);
        let ack_mark = tbuf.as_ref().map(|b| b.now_us());
        let quorum_failed = self.wait_quorum(repl_seq, &mut out);
        if repl_seq.is_some() && self.ack_mode == AckMode::Quorum {
            if let (Some(b), Some(m)) = (tbuf.as_mut(), ack_mark) {
                b.host_span("repl_ack_wait", "repl", m, vec![]);
            }
        }
        if quorum_failed {
            self.seal_trace(tbuf, job, "load", &out, (0, 0), total.elapsed_secs());
            return out;
        }
        self.metrics.graphs_loaded.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.metrics.observe_latency(total.elapsed_secs());
        self.seal_trace(tbuf, job, "load", &out, (0, 0), total.elapsed_secs());
        out
    }

    fn execute_drop(&self, job: &MatchJob, name: &str) -> MatchOutcome {
        let total = Timer::start();
        let mut out = Self::blank(job.id);
        let mut tbuf = self.trace_buf(job);
        // lock order (matches UPDATE/SAVE/eviction): entry mutex first,
        // then the persistence name lock. Holding the entry lock while
        // unmapping serializes against in-flight UPDATEs (they commit
        // before us or observe themselves unmapped); holding the name
        // lock across marker + unmap + deletion keeps a concurrent
        // transparent reload from resurrecting the graph out of the
        // not-yet-deleted files.
        let entry = self.store.entry(name);
        let entry_guard = entry.as_ref().map(|e| lockorder::lock(LockClass::Entry, e));
        let in_memory = entry_guard.is_some();
        let version = entry_guard.as_ref().map(|e| e.graph.version());
        let name_lock = self.persist.as_ref().map(|p| p.name_lock(name));
        let name_guard = name_lock.as_ref().map(|l| lockorder::lock(LockClass::Name, l));
        let on_disk = self
            .persist
            .as_ref()
            .is_some_and(|p| p.has_state_locked(name));
        if !in_memory && !on_disk {
            self.fail(&mut out, JobError::Load(format!("no stored graph named {name:?}")));
            return out;
        }
        if let Some(p) = &self.persist {
            if on_disk {
                // the fsync'd marker is the commit point: fail *before*
                // touching memory if it can't be written (the graph stays
                // fully intact); after it, file deletion is best-effort —
                // recovery completes an interrupted drop from the marker
                let wal_mark = tbuf.as_ref().map(|b| b.now_us());
                if let Err(e) = p.append_drop_marker_locked(name, version) {
                    self.fail(
                        &mut out,
                        JobError::Load(format!("dropping {name:?} on disk failed: {e}")),
                    );
                    return out;
                }
                self.metrics.wal_appends.fetch_add(1, Ordering::Relaxed);
                if let (Some(b), Some(m)) = (tbuf.as_mut(), wal_mark) {
                    b.host_span("wal_fsync", "persist", m, vec![]);
                }
            }
        }
        // ship the drop (as the same version-scoped frame the WAL holds)
        // before unmapping, still under the locks that order this name's
        // event stream
        let mut repl_seq = None;
        if self.replicating() {
            let frame = wal::encode_frame(&wal::WalRecord::Drop {
                version: version.unwrap_or(0),
            });
            repl_seq = Some(self.publish_event(EventKind::Frame, name, frame));
        }
        self.store.drop_graph(name);
        drop(entry_guard);
        if let Some(p) = &self.persist {
            if on_disk {
                p.delete_graph_files_locked(name);
            }
        }
        drop(name_guard);
        drop(name_lock);
        if let Some(p) = &self.persist {
            p.release_name_lock_if_unused(name);
        }
        let ack_mark = tbuf.as_ref().map(|b| b.now_us());
        let quorum_failed = self.wait_quorum(repl_seq, &mut out);
        if repl_seq.is_some() && self.ack_mode == AckMode::Quorum {
            if let (Some(b), Some(m)) = (tbuf.as_mut(), ack_mark) {
                b.host_span("repl_ack_wait", "repl", m, vec![]);
            }
        }
        if quorum_failed {
            self.seal_trace(tbuf, job, "drop", &out, (0, 0), total.elapsed_secs());
            return out;
        }
        self.metrics.graphs_dropped.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.metrics.observe_latency(total.elapsed_secs());
        self.seal_trace(tbuf, job, "drop", &out, (0, 0), total.elapsed_secs());
        out
    }

    fn execute_save(&self, job: &MatchJob, name: &str) -> MatchOutcome {
        let total = Timer::start();
        let mut out = Self::blank(job.id);
        let mut tbuf = self.trace_buf(job);
        let Some(p) = &self.persist else {
            self.fail(
                &mut out,
                JobError::Unavailable("SAVE requires a data dir (serve --data-dir)".into()),
            );
            return out;
        };
        let Some(entry) = self.store.entry(name) else {
            self.fail(
                &mut out,
                JobError::Load(format!("no stored graph named {name:?} (LOAD it first)")),
            );
            return out;
        };
        let mut e = lockorder::lock(LockClass::Entry, &entry);
        let g = e.graph.snapshot();
        let version = e.graph.version();
        let matching = e
            .matching
            .as_ref()
            .filter(|c| c.version == version)
            .map(|c| c.matching.clone());
        out.nr = g.nr;
        out.nc = g.nc;
        out.n_edges = g.n_edges();
        let snap_mark = tbuf.as_ref().map(|b| b.now_us());
        if let Err(err) = p.record_snapshot(name, &g, version, matching.as_ref()) {
            drop(e);
            self.fail(&mut out, JobError::Load(format!("snapshotting {name:?} failed: {err}")));
            return out;
        }
        e.stats.snapshots += 1;
        drop(e);
        if let (Some(b), Some(m)) = (tbuf.as_mut(), snap_mark) {
            b.host_span("snapshot_write", "persist", m, vec![("edges", out.n_edges as u64)]);
        }
        if let Some(obs) = &self.obs {
            obs.event(Level::Info, "wal_compacted")
                .field("graph", name)
                .field_u64("version", version)
                .field("trigger", "save")
                .emit();
        }
        self.metrics.snapshots_written.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.metrics.observe_latency(total.elapsed_secs());
        self.seal_trace(tbuf, job, "save", &out, (0, 0), total.elapsed_secs());
        out
    }

    fn execute_update(&self, job: &MatchJob, name: &str, batch: &DeltaBatch) -> MatchOutcome {
        let total = Timer::start();
        let (deadline, budget_ms) = effective_deadline(job, Instant::now());
        let mut out = Self::blank(job.id);
        let mut tbuf = self.trace_buf(job);
        let Some(entry) = self.store.entry(name).or_else(|| self.reload_from_disk(name))
        else {
            self.fail(
                &mut out,
                JobError::Load(format!("no stored graph named {name:?} (LOAD it first)")),
            );
            return out;
        };
        // the entry lock is held across apply + repair: updates to one
        // graph serialize (the cache is only meaningful under per-graph
        // ordering) while other graphs keep flowing
        let mut e = lockorder::lock(LockClass::Entry, &entry);
        // resolve AND validate the spec before mutating anything: an
        // unbuildable spec (xla without an engine) must reply ERR with the
        // stored graph untouched — a half-applied update behind an error
        // reply would desynchronize client and server views. GPU specs
        // skip the probe (repair constructs them directly, and they always
        // build); for the rest the probe is a Box of a unit struct.
        let spec = self.resolve_spec(job, &e.graph.snapshot());
        out.algo = spec.to_string();
        if !matches!(
            spec,
            super::spec::AlgoSpec::Gpu(_) | super::spec::AlgoSpec::Sharded { .. }
        )
            && registry::build(&spec, self.engine.clone()).is_none()
        {
            self.fail(&mut out, JobError::Unavailable(registry::unavailable_msg(&spec)));
            return out;
        }
        // UPDATE is transactional: ERR means the stored graph did NOT
        // advance. The pre-batch state is cheap to keep (Arc'd base CSR +
        // the overlay maps + one matching clone) and is restored on every
        // failure path below, so wire clients can always retry an ERR'd
        // batch without double-applying it.
        let graph_backup = e.graph.clone();
        let cached_prev = e.matching.take();

        let apply_mark = tbuf.as_ref().map(|b| b.now_us());
        let report = e.graph.apply(batch);
        let g = e.graph.snapshot();
        out.t_load = total.elapsed_secs();
        out.nr = g.nr;
        out.nc = g.nc;
        out.n_edges = g.n_edges();
        let mut update = UpdateStats {
            inserted: report.inserted.len() as u64,
            deleted: report.deleted.len() as u64,
            cols_added: report.added_cols.len() as u64,
            rows_added: report.added_rows.len() as u64,
            rejected: report.rejected as u64,
            rebuilt: report.rebuilt,
            ..UpdateStats::default()
        };
        if let (Some(b), Some(m)) = (tbuf.as_mut(), apply_mark) {
            b.host_span(
                "apply",
                "job",
                m,
                vec![
                    ("inserted", update.inserted),
                    ("deleted", update.deleted),
                    ("rebuilt", u64::from(update.rebuilt)),
                ],
            );
        }

        let t_init = Timer::start();
        // warm start: the maintained matching, or a fresh init heuristic
        // the first time this graph is ever matched
        let prev = match &cached_prev {
            Some(c) => c.matching.clone(),
            None => job.init.run(&g),
        };
        out.t_init = t_init.elapsed_secs();

        let mut ctx = RunCtx::new(self.pool.clone()).with_cancel(self.cancel.clone());
        ctx.set_deadline(deadline);
        let solve_mark = tbuf.as_ref().map(|b| b.now_us());
        if let Some(b) = tbuf.take() {
            ctx.arm_trace(b); // repair's phase + kernel spans go here
        }
        let t_match = Timer::start();
        // with buildability checked above, this Err is the defensive
        // matching/graph-shape mismatch only — unreachable from the store
        // flow, where the matching is maintained under this entry's lock
        let summary =
            match dynamic::repair(&g, prev, &report, &spec, self.engine.clone(), &mut ctx) {
                Ok(s) => s,
                Err(msg) => {
                    e.graph = graph_backup;
                    e.matching = cached_prev;
                    out.update = Some(update);
                    self.fail(&mut out, JobError::Unavailable(msg));
                    return out;
                }
            };
        out.t_match = t_match.elapsed_secs();
        tbuf = ctx.take_trace();
        update.seeds = summary.seeds as u64;
        update.dropped = summary.dropped as u64;
        update.joined = summary.joined as u64;
        out.update = Some(update);
        out.init_cardinality = summary.start_cardinality;
        let result = summary.result;
        out.cardinality = result.matching.cardinality();
        out.phases = result.stats.phases;
        out.frontier_peak = result.stats.frontier_peak;
        out.endpoints_total = result.stats.endpoints_total;
        out.device_parallel_cycles = result.stats.device_parallel_cycles;
        out.shards = result.stats.shards;
        out.exchange_words = result.stats.exchange_words;
        out.exchange_steps = result.stats.exchange_steps;
        let solve_detail = (
            result.stats.launches_per_phase.iter().map(|&l| l as u64).sum::<u64>(),
            result.stats.device_cycles,
        );
        if let (Some(b), Some(m)) = (tbuf.as_mut(), solve_mark) {
            b.host_span(
                "solve",
                "job",
                m,
                vec![
                    ("phases", result.stats.phases),
                    ("launches", solve_detail.0),
                    ("seeds", update.seeds),
                ],
            );
        }

        // decide the fate under the entry lock so the rollback can never
        // clobber a concurrent update's work (updates to one graph
        // serialize on this lock)
        let complete = result.outcome == RunOutcome::Complete;
        let cert_mark = tbuf.as_ref().map(|b| b.now_us());
        let certify_err = if complete && job.certify {
            result.matching.certify(&g).err()
        } else {
            None
        };
        if complete && job.certify && certify_err.is_none() {
            if let (Some(b), Some(m)) = (tbuf.as_mut(), cert_mark) {
                b.host_span("certify", "job", m, vec![]);
            }
        }
        if !complete || certify_err.is_some() {
            e.graph = graph_backup;
            e.matching = cached_prev;
            drop(e);
            match result.outcome {
                RunOutcome::DeadlineExceeded => {
                    self.metrics.jobs_timed_out.fetch_add(1, Ordering::Relaxed);
                    self.fail(&mut out, JobError::DeadlineExceeded { timeout_ms: budget_ms });
                }
                RunOutcome::Cancelled => {
                    self.metrics.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                    self.fail(&mut out, JobError::Cancelled);
                }
                RunOutcome::Complete => {
                    // certification failed: the graph state is fine but
                    // the repaired matching is untrusted — roll back
                    // rather than serve or cache it
                    self.metrics.certify_failures.fetch_add(1, Ordering::Relaxed);
                    self.fail(
                        &mut out,
                        JobError::Certify(certify_err.expect("checked above")),
                    );
                }
            }
            self.metrics.record_spec(&out.algo, total.elapsed_secs(), false, solve_detail.1);
            self.seal_trace(tbuf, job, "update", &out, solve_detail, total.elapsed_secs());
            return out;
        }
        out.certified = job.certify;

        // a concurrent DROP or re-LOAD may have unmapped this entry while
        // the repair ran: the work landed on an orphan, and the client
        // must not be told the stored graph advanced — nor may the batch
        // reach the (deleted or reset) WAL. Checked while still holding
        // the entry lock: DROP and eviction also take it before
        // unmapping, so for those the answer cannot flip between here and
        // commit. A re-LOAD does *not* take the old entry's lock, so an
        // update that passes this check can still commit concurrently
        // with a re-LOAD of the name — that interleaving is the valid
        // linearization "update, then replace", and the update's frame,
        // if the re-LOAD's WAL reset wins the race, carries the old
        // incarnation's version and is filtered out by replay.
        let still_mapped =
            self.store.entry(name).is_some_and(|cur| Arc::ptr_eq(&cur, &entry));
        if !still_mapped {
            e.graph = graph_backup;
            e.matching = cached_prev;
            drop(e);
            self.fail(
                &mut out,
                JobError::Load(format!(
                    "stored graph {name:?} was dropped or replaced mid-update"
                )),
            );
            self.metrics.record_spec(&out.algo, total.elapsed_secs(), false, solve_detail.1);
            self.seal_trace(tbuf, job, "update", &out, solve_detail, total.elapsed_secs());
            return out;
        }

        // write-ahead before acknowledgement: the batch's net effect (and
        // the report it produced) is fsync'd into the WAL under the entry
        // lock; a failed append rolls the whole update back. The invariant
        // wire clients get: an acknowledged UPDATE is always recoverable,
        // an ERR'd one was never persisted. No-op batches (every op
        // rejected) change nothing and are not logged.
        if let Some(p) = &self.persist {
            if !report.is_noop() {
                let wal_mark = tbuf.as_ref().map(|b| b.now_us());
                if let Err(err) = p.append_update(name, e.graph.version(), &report) {
                    e.graph = graph_backup;
                    e.matching = cached_prev;
                    drop(e);
                    self.fail(
                        &mut out,
                        JobError::Load(format!("WAL append for {name:?} failed: {err}")),
                    );
                    self.metrics.record_spec(&out.algo, total.elapsed_secs(), false, solve_detail.1);
                    self.seal_trace(tbuf, job, "update", &out, solve_detail, total.elapsed_secs());
                    return out;
                }
                self.metrics.wal_appends.fetch_add(1, Ordering::Relaxed);
                e.stats.wal_appends += 1;
                if let (Some(b), Some(m)) = (tbuf.as_mut(), wal_mark) {
                    b.host_span("wal_fsync", "persist", m, vec![]);
                }
            }
        }

        // ship the committed frame while still holding the entry lock —
        // updates to one graph serialize on it, so stream order matches
        // commit order. The bytes are exactly what the WAL appended
        // (same `update_record`), so the follower replays the identical
        // incarnation-scoped frame recovery would.
        let mut repl_seq = None;
        if !report.is_noop() && self.replicating() {
            let frame =
                wal::encode_frame(&persist::update_record(e.graph.version(), &report));
            repl_seq = Some(self.publish_event(EventKind::Frame, name, frame));
        }

        // success: the batch is durable — per-graph stats and the new
        // maintained matching land together
        e.stats.updates += 1;
        e.stats.edges_inserted += update.inserted;
        e.stats.edges_deleted += update.deleted;
        e.stats.cols_added += update.cols_added;
        e.stats.rows_added += update.rows_added;
        e.stats.repairs += 1;
        let version = e.graph.version();
        e.matching = Some(CachedMatching { matching: result.matching, version });

        // snapshot piggyback: a batch that tripped the threshold rebuild
        // just paid the O(E) CSR materialization, so persisting that CSR
        // (and compacting the WAL it covers) is marginal cost. Best
        // effort: on failure the WAL still covers the batch, and the next
        // rebuild or SAVE retries.
        if report.rebuilt {
            if let Some(p) = &self.persist {
                let snap_mark = tbuf.as_ref().map(|b| b.now_us());
                let g_snap = e.graph.snapshot();
                let m = e.matching.as_ref().map(|c| c.matching.clone());
                if p.record_snapshot(name, &g_snap, version, m.as_ref()).is_ok() {
                    self.metrics.snapshots_written.fetch_add(1, Ordering::Relaxed);
                    e.stats.snapshots += 1;
                    if let (Some(b), Some(m)) = (tbuf.as_mut(), snap_mark) {
                        b.host_span("snapshot_write", "persist", m, vec![]);
                    }
                    if let Some(obs) = &self.obs {
                        obs.event(Level::Info, "wal_compacted")
                            .field("graph", name)
                            .field_u64("version", version)
                            .field("trigger", "rebuild")
                            .emit();
                    }
                }
            }
        }
        drop(e);

        let ack_mark = tbuf.as_ref().map(|b| b.now_us());
        let quorum_failed = self.wait_quorum(repl_seq, &mut out);
        if repl_seq.is_some() && self.ack_mode == AckMode::Quorum {
            if let (Some(b), Some(m)) = (tbuf.as_mut(), ack_mark) {
                b.host_span("repl_ack_wait", "repl", m, vec![]);
            }
        }
        if quorum_failed {
            self.metrics.record_spec(&out.algo, total.elapsed_secs(), false, solve_detail.1);
            self.seal_trace(tbuf, job, "update", &out, solve_detail, total.elapsed_secs());
            return out;
        }
        self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_updated.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .edges_processed
            .fetch_add(out.n_edges as u64, Ordering::Relaxed);
        self.metrics
            .matched_total
            .fetch_add(out.cardinality as u64, Ordering::Relaxed);
        self.metrics.observe_latency(total.elapsed_secs());
        self.metrics.record_spec(&out.algo, total.elapsed_secs(), true, solve_detail.1);
        self.seal_trace(tbuf, job, "update", &out, solve_detail, total.elapsed_secs());
        out
    }

    /// Crash-promoted failover: turn this replica (or fenced ex-primary)
    /// into the writable primary. Fences the dead primary by bumping the
    /// epoch past anything ever seen from it, and re-bases every stored
    /// graph into a fresh incarnation of the `version >> 32` space — so
    /// a frame from the old primary's incarnations can never replay over
    /// promoted state, and a rejoining ex-primary is rejected (and
    /// self-fences) on its first handshake. Returns `(epoch, graphs)`.
    pub fn promote(&self) -> Result<(u64, usize), String> {
        if self.role.is_writable() {
            return Err("not a replica: this node is already writable".into());
        }
        // stop the tailer first so no replicated event lands mid-re-base
        self.role.promoted.store(true, Ordering::Relaxed);
        let new_epoch = self
            .role
            .epoch()
            .max(self.role.primary_epoch_seen.load(Ordering::Relaxed))
            + 1;
        self.role.epoch.store(new_epoch, Ordering::Relaxed);
        if let Some(p) = &self.persist {
            replicate::write_epoch(p.dir(), new_epoch)
                .map_err(|e| format!("persisting epoch {new_epoch}: {e}"))?;
        }
        let mut rebased = 0usize;
        for name in self.store.names() {
            let Some(entry) = self.store.entry(&name) else { continue };
            let mut e = lockorder::lock(LockClass::Entry, &entry);
            let g = e.graph.snapshot();
            let old_version = e.graph.version();
            let matching = e
                .matching
                .as_ref()
                .filter(|c| c.version == old_version)
                .map(|c| c.matching.clone());
            let base = self.store.allocate_version_base();
            if let Some(p) = &self.persist {
                // the new incarnation's anchor snapshot (carrying the
                // replicated matching) plus WAL compaction — recovery of
                // the promoted node never replays pre-promotion frames
                p.record_snapshot(&name, &g, base, matching.as_ref())
                    .map_err(|e| format!("re-basing {name:?} at promotion: {e}"))?;
                self.metrics.snapshots_written.fetch_add(1, Ordering::Relaxed);
            }
            e.graph = DynamicGraph::from_arc(g).with_version_base(base);
            e.matching = matching.map(|m| CachedMatching { matching: m, version: base });
            rebased += 1;
        }
        self.role.read_only.store(false, Ordering::Relaxed);
        self.role.fenced.store(false, Ordering::Relaxed);
        if let Some(obs) = &self.obs {
            obs.event(Level::Warn, "promoted")
                .field_u64("epoch", new_epoch)
                .field_u64("graphs_rebased", rebased as u64)
                .emit();
        }
        Ok((new_epoch, rebased))
    }

    /// Install one replicated event — the follower half of the tailer
    /// loop. `Err` makes the tailer drop the connection and resync from
    /// a fresh baseline.
    pub fn apply_replicated_event(&self, ev: &Event) -> Result<(), String> {
        match ev.kind {
            EventKind::Snap => self.apply_replicated_snapshot(ev),
            EventKind::Frame => self.apply_replicated_frame(ev),
        }
    }

    fn apply_replicated_snapshot(&self, ev: &Event) -> Result<(), String> {
        let snap = snapshot::decode_snapshot(&ev.data)
            .ok_or_else(|| format!("undecodable snapshot image for {:?}", ev.name))?;
        // durability before the ack: a durable follower persists what it
        // acknowledges, so its own crash recovery reproduces this state
        if let Some(p) = &self.persist {
            p.record_snapshot(&ev.name, &snap.graph, snap.version, snap.matching.as_ref())
                .map_err(|e| format!("persisting replicated snapshot: {e}"))?;
            self.metrics.snapshots_written.fetch_add(1, Ordering::Relaxed);
        }
        let version = snap.version;
        let dg = DynamicGraph::from_arc(Arc::new(snap.graph)).with_version_base(version);
        let cached = snap.matching.map(|m| CachedMatching { matching: m, version });
        self.store.install(&ev.name, dg, cached);
        self.metrics.repl_frames_applied.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn apply_replicated_frame(&self, ev: &Event) -> Result<(), String> {
        let (records, torn) = wal::parse_frames(&ev.data);
        if torn || records.len() != 1 {
            return Err(format!("malformed frame event for {:?}", ev.name));
        }
        match records.into_iter().next().expect("checked len") {
            // baselines and re-bases ship as snapshot events; a LOAD
            // marker frame carries no graph and is skipped if ever seen
            wal::WalRecord::Load { .. } => Ok(()),
            wal::WalRecord::Drop { version } => {
                if let Some(p) = &self.persist {
                    p.record_drop(&ev.name, Some(version))
                        .map_err(|e| format!("persisting replicated drop: {e}"))?;
                }
                self.store.drop_graph(&ev.name);
                self.metrics.graphs_dropped.fetch_add(1, Ordering::Relaxed);
                self.metrics.repl_frames_applied.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            wal::WalRecord::Update { version_after, batch_wire, report_wire } => {
                let entry = self.store.entry(&ev.name).ok_or_else(|| {
                    format!("frame for graph {:?} with no baseline — resync", ev.name)
                })?;
                let mut e = lockorder::lock(LockClass::Entry, &entry);
                let floor = e.graph.version();
                // the same replay kernel as crash recovery: incarnation
                // scoping, ≤-floor skip, gap halt, report cross-check
                match recover::apply_update_frame(
                    &mut e.graph,
                    floor >> 32,
                    floor,
                    version_after,
                    &batch_wire,
                    &report_wire,
                ) {
                    recover::FrameStep::Skipped => Ok(()),
                    recover::FrameStep::Halt => Err(format!(
                        "frame v{version_after} does not extend v{floor} for {:?} — resync",
                        ev.name
                    )),
                    recover::FrameStep::Applied(report) => {
                        let version = e.graph.version();
                        // patch the maintained matching forward by seeded
                        // repair (same as recovery). Best-effort: on any
                        // failure the graph still advances and the cache
                        // drops — a promoted follower's next MATCH then
                        // runs cold rather than serving untrusted state.
                        let prev = e
                            .matching
                            .take()
                            .filter(|c| c.version == floor)
                            .map(|c| c.matching);
                        if let Some(prev) = prev {
                            let live = e.graph.snapshot();
                            let spec = router::route_graph(&live);
                            let mut ctx = RunCtx::new(self.pool.clone());
                            if let Ok(summary) = dynamic::repair(
                                &live,
                                prev,
                                &report,
                                &spec,
                                self.engine.clone(),
                                &mut ctx,
                            ) {
                                if summary.result.outcome == RunOutcome::Complete
                                    && summary.result.matching.certify(&live).is_ok()
                                {
                                    e.matching = Some(CachedMatching {
                                        matching: summary.result.matching,
                                        version,
                                    });
                                }
                            }
                        }
                        if let Some(p) = &self.persist {
                            p.append_update(&ev.name, version, &report)
                                .map_err(|e| format!("persisting replicated frame: {e}"))?;
                            self.metrics.wal_appends.fetch_add(1, Ordering::Relaxed);
                        }
                        e.stats.updates += 1;
                        self.metrics.repl_frames_applied.fetch_add(1, Ordering::Relaxed);
                        Ok(())
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::MatchJob;
    use crate::graph::gen::Family;

    fn exec() -> Executor {
        Executor::new(None, Arc::new(Metrics::new()))
    }

    #[test]
    fn executes_generated_job_auto_routing() {
        let job = MatchJob::new(
            1,
            GraphSource::Generate { family: Family::Uniform, n: 500, seed: 2, permute: false },
        );
        let out = exec().execute(&job);
        assert!(out.error.is_none(), "{:?}", out.error);
        assert!(out.certified);
        assert!(out.cardinality > 0);
        assert!(out.cardinality >= out.init_cardinality);
        assert!(!out.algo.is_empty());
        assert!(out.update.is_none(), "match jobs carry no update stats");
    }

    #[test]
    fn named_algorithm_respected() {
        let job = MatchJob::new(
            2,
            GraphSource::Generate { family: Family::Banded, n: 300, seed: 1, permute: true },
        )
        .with_algo("hkdw");
        let out = exec().execute(&job);
        assert_eq!(out.algo, "hkdw");
        assert!(out.certified);
    }

    #[test]
    fn unavailable_backend_is_a_distinct_error() {
        // xla specs parse fine but cannot build without an engine
        let job = MatchJob::new(
            3,
            GraphSource::Generate { family: Family::Uniform, n: 50, seed: 1, permute: false },
        )
        .with_algo("xla:apfb-full");
        let out = exec().execute(&job);
        assert!(matches!(out.error, Some(JobError::Unavailable(_))), "{:?}", out.error);
        assert_eq!(out.algo, "xla:apfb-full");
    }

    #[test]
    fn missing_mtx_is_error_not_panic() {
        let job = MatchJob::new(4, GraphSource::MtxFile("/no/such/file.mtx".into()));
        let out = exec().execute(&job);
        assert!(matches!(out.error, Some(JobError::Load(_))));
    }

    #[test]
    fn frontier_override_normalizes_gpu_picks_only() {
        use crate::gpu::FrontierMode;
        let mk = |seed| {
            MatchJob::new(
                seed,
                GraphSource::Generate { family: Family::Uniform, n: 200, seed, permute: false },
            )
        };
        // explicit "gpu" alias + compacted → the "-FC" twin runs
        let out = exec().execute(&mk(0).with_algo("gpu").with_frontier(FrontierMode::Compacted));
        assert_eq!(out.algo, "gpu:APFB-GPUBFS-WR-CT-FC");
        assert!(out.certified);
        // an "-FC" name + fullscan override → compaction disabled
        let job = mk(1).with_algo("gpu:APsB-GPUBFS-CT-FC").with_frontier(FrontierMode::FullScan);
        let out = exec().execute(&job);
        assert_eq!(out.algo, "gpu:APsB-GPUBFS-CT");
        // CPU picks are untouched by the override
        let out = exec().execute(&mk(2).with_algo("pfp").with_frontier(FrontierMode::Compacted));
        assert_eq!(out.algo, "pfp");
        assert!(out.certified);
    }

    #[test]
    fn in_memory_source() {
        let g = Arc::new(crate::graph::from_edges(2, 2, &[(0, 0), (1, 1)]));
        let job = MatchJob::new(5, GraphSource::InMemory(g)).with_algo("bfs");
        let out = exec().execute(&job);
        assert_eq!(out.cardinality, 2);
        assert!(out.certified);
    }

    #[test]
    fn metrics_accumulate() {
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        for i in 0..3 {
            let job = MatchJob::new(
                i,
                GraphSource::Generate { family: Family::Uniform, n: 100, seed: i, permute: false },
            );
            e.execute(&job);
        }
        assert_eq!(metrics.completed(), 3);
        assert!(metrics.mean_latency() > 0.0);
    }

    #[test]
    fn failed_jobs_do_not_pollute_completion_metrics() {
        // every failure path (acquire, unbuildable algo) must land in
        // jobs_failed and leave jobs_completed / matched_total untouched,
        // so submitted == completed + failed stays an invariant (the
        // certification-failure path shares the same early return)
        use std::sync::atomic::Ordering;
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        let bad_algo = MatchJob::new(
            0,
            GraphSource::Generate { family: Family::Uniform, n: 100, seed: 1, permute: false },
        )
        .with_algo("xla:apfb-full"); // no engine → unavailable
        let missing = MatchJob::new(1, GraphSource::MtxFile("/no/such/file.mtx".into()));
        let good = MatchJob::new(
            2,
            GraphSource::Generate { family: Family::Uniform, n: 100, seed: 2, permute: false },
        );
        for job in [&bad_algo, &missing, &good] {
            e.execute(job);
        }
        assert_eq!(metrics.completed(), 1);
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 2);
        let good_card = e.execute(&good).cardinality as u64;
        assert_eq!(
            metrics.matched_total.load(Ordering::Relaxed),
            2 * good_card,
            "only certified-complete jobs contribute to matched_total"
        );
    }

    #[test]
    fn timed_out_job_fails_distinctly() {
        // a zero deadline trips at the first inter-phase checkpoint, for
        // every backend the job could route to
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        let job = MatchJob::new(
            9,
            GraphSource::Generate { family: Family::Uniform, n: 800, seed: 3, permute: false },
        )
        .with_algo("hk")
        .with_timeout_ms(0);
        let out = e.execute(&job);
        assert_eq!(out.error, Some(JobError::DeadlineExceeded { timeout_ms: 0 }));
        assert!(!out.certified);
        assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.completed(), 0);
    }

    #[test]
    fn absolute_deadline_caps_the_job_like_a_timeout() {
        // the batch-wide budget path: an already-expired absolute deadline
        // trips exactly like timeout_ms=0, with the distinct error
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        let job = MatchJob::new(
            11,
            GraphSource::Generate { family: Family::Uniform, n: 800, seed: 3, permute: false },
        )
        .with_algo("hk")
        .with_deadline_at(Instant::now());
        let out = e.execute(&job);
        assert_eq!(out.error, Some(JobError::DeadlineExceeded { timeout_ms: 0 }));
        assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 1);
        // and the earlier of {timeout, deadline} wins: a generous timeout
        // cannot rescue an expired absolute deadline
        let job = MatchJob::new(
            12,
            GraphSource::Generate { family: Family::Uniform, n: 800, seed: 3, permute: false },
        )
        .with_algo("hk")
        .with_timeout_ms(60_000)
        .with_deadline_at(Instant::now());
        let out = e.execute(&job);
        assert!(
            matches!(out.error, Some(JobError::DeadlineExceeded { .. })),
            "{:?}",
            out.error
        );
    }

    #[test]
    fn cancelled_executor_fails_jobs_distinctly() {
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        e.cancel_token().cancel();
        let job = MatchJob::new(
            10,
            GraphSource::Generate { family: Family::Uniform, n: 400, seed: 1, permute: false },
        )
        .with_algo("pfp");
        let out = e.execute(&job);
        assert_eq!(out.error, Some(JobError::Cancelled));
        assert_eq!(metrics.jobs_cancelled.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn workspace_pool_reused_across_jobs() {
        // the acceptance bar for workspace reuse: a second same-size job
        // through the same executor leases the first job's buffers
        let e = exec();
        let mk = |id| {
            MatchJob::new(
                id,
                GraphSource::Generate { family: Family::Uniform, n: 400, seed: 7, permute: false },
            )
            .with_algo("gpu:APFB-GPUBFS-WR-CT-FC")
        };
        let out = e.execute(&mk(0));
        assert!(out.certified, "{:?}", out.error);
        assert_eq!(e.workspace_pool().reuses(), 0, "first job allocates fresh");
        let returned = e.workspace_pool().returns();
        assert!(returned > 0, "buffers must come back to the pool");
        let out = e.execute(&mk(1));
        assert!(out.certified);
        assert!(
            e.workspace_pool().reuses() >= 3,
            "second same-size job must lease the first job's buffers, reuses={}",
            e.workspace_pool().reuses()
        );
    }

    // ---- incremental verbs through the executor --------------------------

    fn load_job(id: u64, name: &str, n: usize, seed: u64) -> MatchJob {
        MatchJob::load_graph(
            id,
            name,
            GraphSource::Generate { family: Family::Uniform, n, seed, permute: false },
        )
    }

    #[test]
    fn load_update_match_drop_lifecycle() {
        use crate::dynamic::DeltaBatch;
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        // LOAD
        let out = e.execute(&load_job(1, "g", 400, 7));
        assert!(out.error.is_none(), "{:?}", out.error);
        assert!(out.n_edges > 0);
        assert_eq!(e.store().len(), 1);
        // MATCH against the stored graph (cold: no cached matching yet)
        let cold = e.execute(&MatchJob::new(2, GraphSource::Stored("g".into())));
        assert!(cold.certified, "{:?}", cold.error);
        assert!(cold.cardinality > 0);
        // MATCH again: warm-started from the cache, so init == answer and
        // the run closes in a quiet phase
        let warm = e.execute(&MatchJob::new(3, GraphSource::Stored("g".into())));
        assert!(warm.certified);
        assert_eq!(warm.cardinality, cold.cardinality);
        assert_eq!(
            warm.init_cardinality, cold.cardinality,
            "second MATCH must start from the cached maximum"
        );
        // UPDATE: delete a matched edge and insert nothing — repair runs
        let (r, c) = {
            let view = e.store().graph_for_match("g").unwrap();
            let m = view.cached.expect("cache must exist after a certified MATCH").matching;
            let c = (0..m.nc()).find(|&c| m.cmatch[c] >= 0).unwrap();
            (m.cmatch[c] as u32, c as u32)
        };
        let out = e.execute(&MatchJob::update_graph(4, "g", DeltaBatch::new().delete(r, c)));
        assert!(out.error.is_none(), "{:?}", out.error);
        assert!(out.certified);
        let up = out.update.expect("update jobs must carry update stats");
        assert_eq!(up.deleted, 1);
        assert_eq!(up.dropped, 1, "the deleted edge was matched");
        assert!(up.seeds >= 1);
        assert_eq!(metrics.jobs_updated.load(Ordering::Relaxed), 1);
        // the repaired cardinality is within 1 of the old one and MATCH
        // now serves it warm
        assert!(out.cardinality + 1 >= cold.cardinality);
        let after = e.execute(&MatchJob::new(5, GraphSource::Stored("g".into())));
        assert_eq!(after.init_cardinality, out.cardinality);
        // DROP
        let out = e.execute(&MatchJob::drop_graph(6, "g"));
        assert!(out.error.is_none());
        assert!(e.store().is_empty());
        // every verb was a completed job; nothing failed
        assert_eq!(metrics.completed(), 6);
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.graphs_loaded.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.graphs_dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn unknown_stored_graph_is_a_load_error() {
        use crate::dynamic::DeltaBatch;
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        for job in [
            MatchJob::new(0, GraphSource::Stored("nope".into())),
            MatchJob::update_graph(1, "nope", DeltaBatch::new().insert(0, 0)),
            MatchJob::drop_graph(2, "nope"),
        ] {
            let out = e.execute(&job);
            assert!(matches!(out.error, Some(JobError::Load(_))), "{:?}", out.error);
        }
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.completed(), 0);
    }

    #[test]
    fn unbuildable_update_spec_leaves_the_stored_graph_untouched() {
        // regression: an ERR reply must mean "nothing happened" — an xla
        // UPDATE without an engine used to apply the batch (and discard
        // the cached matching) before discovering the spec can't build
        use crate::dynamic::DeltaBatch;
        let e = exec();
        e.execute(&load_job(0, "g", 200, 1));
        let match_out = e.execute(&MatchJob::new(1, GraphSource::Stored("g".into())));
        assert!(match_out.certified);
        let before = e.store().graph_for_match("g").unwrap();
        let out = e.execute(
            &MatchJob::update_graph(2, "g", DeltaBatch::new().add_column(vec![0]))
                .with_algo("xla:apfb-full"),
        );
        assert!(matches!(out.error, Some(JobError::Unavailable(_))), "{:?}", out.error);
        let after = e.store().graph_for_match("g").unwrap();
        assert_eq!(before.version, after.version, "a failed UPDATE must not advance the version");
        assert_eq!(before.graph.nc, after.graph.nc, "the column must not have been appended");
        assert_eq!(
            before.cached.map(|c| c.matching),
            after.cached.map(|c| c.matching),
            "the warm-start cache must survive a rejected UPDATE"
        );
    }

    #[test]
    fn update_repair_matches_fresh_reference() {
        use crate::dynamic::DeltaBatch;
        let e = exec();
        e.execute(&load_job(0, "g", 300, 3));
        e.execute(&MatchJob::new(1, GraphSource::Stored("g".into())));
        // batch: a few deletions + insertions + one appended column
        let m = e.store().graph_for_match("g").unwrap().cached.unwrap().matching;
        let mut batch = DeltaBatch::new().add_column(vec![0, 1, 2]);
        let mut deleted = 0;
        for c in 0..m.nc() {
            if m.cmatch[c] >= 0 && deleted < 3 {
                batch = batch.delete(m.cmatch[c] as u32, c as u32);
                deleted += 1;
            }
        }
        let out = e.execute(&MatchJob::update_graph(2, "g", batch));
        assert!(out.certified, "{:?}", out.error);
        // certification already proves maximality; double-check against
        // the from-scratch oracle on the mutated graph
        let g = e.store().graph_for_match("g").unwrap().graph;
        assert_eq!(out.cardinality, crate::matching::reference_max_cardinality(&g));
    }

    #[test]
    fn update_rollback_is_byte_for_byte_even_across_a_rebuild() {
        // satellite regression: a failed UPDATE whose batch tripped the
        // threshold CSR rebuild mid-apply must restore the pre-batch
        // DynamicGraph byte-for-byte — original base Arc'd CSR, overlay
        // maps, version, rebuild counter, memo — not the rebuilt shape.
        // Both rollback paths that can follow a rebuild are driven: the
        // deadline trip and the repair-rejection (which shares its
        // restore code with the certification-failure path).
        use crate::dynamic::DeltaBatch;
        let e = exec();
        e.execute(&load_job(0, "g", 300, 11));
        e.execute(&MatchJob::new(1, GraphSource::Stored("g".into())));
        // a batch of fresh edges > 25% of the base trips the rebuild
        let g = e.store().graph_for_match("g").unwrap().graph;
        let mut batch = DeltaBatch::new();
        let mut k = 0usize;
        'fill: for r in 0..g.nr {
            for c in 0..g.nc {
                if !g.has_edge(r, c) {
                    batch = batch.insert(r as u32, c as u32);
                    k += 1;
                    if 2 * k > g.n_edges() {
                        break 'fill;
                    }
                }
            }
        }
        let entry = e.store().entry("g").unwrap();
        let before = entry.lock().unwrap().graph.clone();
        {
            let mut probe = before.clone();
            assert!(probe.apply(&batch).rebuilt, "batch must trip the rebuild threshold");
        }
        // path 1: deadline trips after apply (and after the rebuild)
        let out =
            e.execute(&MatchJob::update_graph(2, "g", batch.clone()).with_timeout_ms(0));
        assert!(matches!(out.error, Some(JobError::DeadlineExceeded { .. })), "{:?}", out.error);
        let after = entry.lock().unwrap().graph.clone();
        assert_eq!(before, after, "deadline rollback must be byte-for-byte");
        // path 2: repair rejects the (poisoned) maintained matching after
        // the same rebuild-tripping apply
        let poisoned = {
            let mut guard = entry.lock().unwrap();
            let v = guard.graph.version();
            let bad = CachedMatching {
                matching: Matching::empty(before.nr() + 5, 1),
                version: v,
            };
            guard.matching = Some(bad.clone());
            bad
        };
        let out = e.execute(&MatchJob::update_graph(3, "g", batch));
        assert!(matches!(out.error, Some(JobError::Unavailable(_))), "{:?}", out.error);
        let guard = entry.lock().unwrap();
        assert_eq!(before, guard.graph, "repair-failure rollback must be byte-for-byte");
        assert_eq!(
            guard.matching.as_ref().map(|c| c.version),
            Some(poisoned.version),
            "the pre-batch cache (even a poisoned one) is restored wholesale"
        );
        assert_eq!(before.rebuilds(), guard.graph.rebuilds());
    }

    #[test]
    fn update_with_addrows_flows_through_repair() {
        use crate::dynamic::DeltaBatch;
        let e = exec();
        e.execute(&load_job(0, "g", 300, 13));
        let cold = e.execute(&MatchJob::new(1, GraphSource::Stored("g".into())));
        assert!(cold.certified);
        // append one row wired to three columns and one isolated row
        let batch = DeltaBatch::new().add_row(vec![0, 1, 2]).add_row(vec![]);
        let out = e.execute(&MatchJob::update_graph(2, "g", batch));
        assert!(out.certified, "{:?}", out.error);
        let up = out.update.expect("update stats");
        assert_eq!(up.rows_added, 2);
        assert_eq!(up.inserted, 3);
        // repair ≡ recompute on the grown graph
        let g = e.store().graph_for_match("g").unwrap().graph;
        assert_eq!(g.nr, cold.nr + 2);
        assert_eq!(out.cardinality, crate::matching::reference_max_cardinality(&g));
    }

    // ---- durability through the executor ---------------------------------

    fn temp_data_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bimatch_exec_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn durable_exec(dir: &std::path::Path) -> Executor {
        Executor::new(None, Arc::new(Metrics::new()))
            .with_persistence(Arc::new(crate::persist::Persistence::open(dir).unwrap()))
    }

    #[test]
    fn lru_cap_snapshots_and_transparently_reloads() {
        use crate::coordinator::job::GraphSource;
        let dir = temp_data_dir("lru");
        let e = durable_exec(&dir).with_max_graphs(2);
        e.execute(&load_job(0, "a", 250, 1));
        let a = e.execute(&MatchJob::new(1, GraphSource::Stored("a".into())));
        assert!(a.certified);
        e.execute(&load_job(2, "b", 250, 2));
        e.execute(&load_job(3, "c", 250, 3));
        // "a" is the stalest → snapshotted to disk, evicted from memory
        assert_eq!(e.store().len(), 2);
        assert_eq!(e.store().names(), vec!["b".to_string(), "c".to_string()]);
        assert_eq!(e.metrics.graphs_evicted.load(Ordering::Relaxed), 1);
        // MATCH name=a transparently reloads from disk: identical
        // cardinality, warm-started from the snapshotted matching
        let out = e.execute(&MatchJob::new(4, GraphSource::Stored("a".into())));
        assert!(out.certified, "{:?}", out.error);
        assert_eq!(out.cardinality, a.cardinality);
        assert_eq!(
            out.init_cardinality, a.cardinality,
            "the reloaded graph must warm-start from its recovered matching"
        );
        assert!(e.metrics.graphs_recovered.load(Ordering::Relaxed) >= 1);
        assert_eq!(e.store().len(), 2, "the reload re-enforces the cap");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_cap_without_persistence_discards() {
        let e = exec().with_max_graphs(1);
        e.execute(&load_job(0, "a", 150, 1));
        e.execute(&load_job(1, "b", 150, 2));
        assert_eq!(e.store().len(), 1);
        let out = e.execute(&MatchJob::new(2, GraphSource::Stored("a".into())));
        assert!(matches!(out.error, Some(JobError::Load(_))), "{:?}", out.error);
    }

    #[test]
    fn save_job_snapshots_and_compacts() {
        use crate::dynamic::DeltaBatch;
        let dir = temp_data_dir("save");
        let e = durable_exec(&dir);
        let p = e.persistence().unwrap().clone();
        e.execute(&load_job(0, "g", 200, 5));
        e.execute(&MatchJob::new(1, GraphSource::Stored("g".into())));
        e.execute(&MatchJob::update_graph(2, "g", DeltaBatch::new().add_column(vec![0, 1])));
        assert!(e.metrics.wal_appends.load(Ordering::Relaxed) >= 2, "LOAD marker + UPDATE");
        let out = e.execute(&MatchJob::save_graph(3, "g"));
        assert!(out.error.is_none(), "{:?}", out.error);
        assert!(e.metrics.snapshots_written.load(Ordering::Relaxed) >= 2);
        // compaction: the WAL is empty, the snapshot anchors recovery
        let (records, torn) = crate::persist::wal::read_wal(&p.wal_path("g")).unwrap();
        assert!(records.is_empty() && !torn, "SAVE must truncate the WAL");
        let rec = p.recover_graph("g").unwrap().expect("recoverable after SAVE");
        assert_eq!(rec.replayed_updates, 0);
        assert!(rec.matching.is_some(), "SAVE persists the maintained matching");
        // SAVE without persistence is a distinct, typed refusal
        let volatile = exec();
        volatile.execute(&load_job(0, "g", 100, 1));
        let out = volatile.execute(&MatchJob::save_graph(1, "g"));
        assert!(matches!(out.error, Some(JobError::Unavailable(_))), "{:?}", out.error);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn update_with_zero_deadline_rolls_back_the_batch() {
        // UPDATE is transactional: a deadline-tripped repair must reply
        // with the distinct timeout error AND restore the pre-batch graph
        // and matching, so wire clients can retry the identical batch
        // without double-applying it
        use crate::dynamic::DeltaBatch;
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        e.execute(&load_job(0, "g", 400, 9));
        e.execute(&MatchJob::new(1, GraphSource::Stored("g".into())));
        let view = e.store().graph_for_match("g").unwrap();
        let (g_before, v_before) = (view.graph.clone(), view.version);
        let m = view.cached.unwrap().matching;
        let c = (0..m.nc()).find(|&c| m.cmatch[c] >= 0).unwrap();
        let batch = DeltaBatch::new()
            .delete(m.cmatch[c] as u32, c as u32)
            .add_column(vec![0, 1]);
        let out = e.execute(&MatchJob::update_graph(2, "g", batch).with_timeout_ms(0));
        assert_eq!(out.error, Some(JobError::DeadlineExceeded { timeout_ms: 0 }));
        assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.jobs_updated.load(Ordering::Relaxed), 0);
        // the batch was rolled back wholesale: same version, same shape,
        // and the old maximum still cached as the warm start
        let view = e.store().graph_for_match("g").unwrap();
        assert_eq!(view.version, v_before, "rollback must restore the graph version");
        assert_eq!(view.graph.nc, g_before.nc, "the appended column must be gone");
        assert_eq!(view.graph.n_edges(), g_before.n_edges());
        let cached = view.cached.expect("the pre-update cache must survive");
        assert_eq!(cached.matching, m);
    }
}
