//! Algorithm auto-selection, operationalizing the paper's experimental
//! findings (§4): the GPU algorithm (APFB-GPUBFS-WR-CT) wins in the
//! majority of cases, *except* on matrices whose original ordering makes
//! DFS+lookahead nearly free (narrow banded structure — Hamrle3 finishes
//! in 0.04 s under PFP vs 1.36 s on the GPU). The router measures cheap
//! structural features and picks accordingly.

use super::spec::{AlgoSpec, SeqKind};
use crate::gpu::GpuConfig;
use crate::graph::csr::BipartiteCsr;

/// Cheap structural features (O(sampled edges)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GraphFeatures {
    pub nr: usize,
    pub nc: usize,
    pub n_edges: usize,
    pub avg_col_degree: f64,
    pub max_col_degree: usize,
    /// mean normalized |r/nr - c/nc| over sampled edges: ~0 for banded /
    /// diagonal-dominant orderings, ~1/3 for random permutations
    pub bandedness: f64,
    /// max/avg degree ratio (skew; power-law graphs are large)
    pub degree_skew: f64,
}

pub fn features(g: &BipartiteCsr) -> GraphFeatures {
    let n_edges = g.n_edges();
    let avg = g.avg_col_degree();
    let maxd = g.max_col_degree();
    // sample up to 4096 edges evenly for the bandedness estimate
    let mut band_sum = 0.0;
    let mut samples = 0usize;
    if n_edges > 0 && g.nr > 0 && g.nc > 0 {
        let step = (n_edges / 4096).max(1);
        let mut c = 0usize;
        let mut idx = 0usize;
        while idx < n_edges {
            while g.cxadj[c + 1] as usize <= idx {
                c += 1;
            }
            let r = g.cadj[idx] as usize;
            band_sum += (r as f64 / g.nr as f64 - c as f64 / g.nc as f64).abs();
            samples += 1;
            idx += step;
        }
    }
    GraphFeatures {
        nr: g.nr,
        nc: g.nc,
        n_edges,
        avg_col_degree: avg,
        max_col_degree: maxd,
        bandedness: if samples > 0 { band_sum / samples as f64 } else { 0.0 },
        degree_skew: if avg > 0.0 { maxd as f64 / avg } else { 0.0 },
    }
}

/// Pick a typed spec for the graph.
pub fn route(f: &GraphFeatures) -> AlgoSpec {
    if f.n_edges == 0 {
        return AlgoSpec::Seq(SeqKind::Dfs); // trivial
    }
    // tiny problems: sequential DFS beats any launch overhead
    if f.n_edges < 20_000 {
        return AlgoSpec::Seq(SeqKind::Pfp);
    }
    // banded original orderings: PFP's lookahead resolves almost every
    // column instantly (the paper's Hamrle3 case)
    if f.bandedness < 0.02 && f.degree_skew < 8.0 {
        return AlgoSpec::Seq(SeqKind::Pfp);
    }
    // everything else: the paper's winning GPU variant, in its
    // frontier-compacted form — worklist-driven BFS sweeps and endpoint-
    // list ALTERNATE undercut the full-scan twin's modeled device time
    // wherever late BFS levels go sparse (bench_frontier ablates the
    // promotion across every generator family)
    AlgoSpec::Gpu(GpuConfig::default().compacted())
}

/// Convenience: features + route in one call.
pub fn route_graph(g: &BipartiteCsr) -> AlgoSpec {
    route(&features(g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::Family;

    #[test]
    fn features_sane_on_banded() {
        let g = crate::graph::gen::banded(3000, 12, 0.5, 3);
        let f = features(&g);
        assert!(f.bandedness < 0.02, "banded bandedness = {}", f.bandedness);
        assert!(f.degree_skew < 8.0);
    }

    #[test]
    fn features_sane_on_permuted() {
        let g = crate::graph::gen::banded(3000, 12, 0.5, 3);
        let p = crate::graph::random_permute(&g, 7);
        let f = features(&p);
        assert!(f.bandedness > 0.1, "permuted bandedness = {}", f.bandedness);
    }

    #[test]
    fn router_prefers_pfp_on_banded_gpu_on_permuted() {
        let g = crate::graph::gen::banded(8000, 16, 0.6, 5);
        assert_eq!(route_graph(&g), AlgoSpec::Seq(SeqKind::Pfp));
        let p = crate::graph::random_permute(&g, 11);
        assert_eq!(route_graph(&p).to_string(), "gpu:APFB-GPUBFS-WR-CT-FC");
    }

    #[test]
    fn router_gpu_on_powerlaw() {
        let g = Family::Kron.generate(8192, 3);
        if g.n_edges() >= 20_000 {
            assert_eq!(route_graph(&g).to_string(), "gpu:APFB-GPUBFS-WR-CT-FC");
        }
    }

    #[test]
    fn router_default_gpu_pick_is_frontier_compacted() {
        // the promotion: whatever graph lands on the GPU must get the
        // compacted frontier mode — a typed field now, not a "-FC"
        // suffix — and that spec must be buildable from the registry
        use crate::gpu::FrontierMode;
        let g = crate::graph::random_permute(&crate::graph::gen::banded(8000, 16, 0.6, 5), 3);
        let spec = route_graph(&g);
        let AlgoSpec::Gpu(cfg) = spec else {
            panic!("permuted banded must route to the GPU, got {spec}")
        };
        assert_eq!(cfg.frontier, FrontierMode::Compacted);
        assert!(crate::coordinator::registry::build(&spec, None).is_some());
    }

    #[test]
    fn router_trivial_cases() {
        let empty = crate::graph::from_edges(4, 4, &[]);
        assert_eq!(route_graph(&empty), AlgoSpec::Seq(SeqKind::Dfs));
        let small = crate::graph::from_edges(3, 3, &[(0, 0), (1, 1)]);
        assert_eq!(route_graph(&small), AlgoSpec::Seq(SeqKind::Pfp));
    }

    #[test]
    fn auto_routed_algorithm_reaches_reference_on_every_family() {
        // whatever the router picks — pfp, dfs, or the new "-FC" GPU
        // default — must reach the reference cardinality on every
        // generator family, both original and permuted orderings
        use crate::matching::{reference_max_cardinality, Matching};
        let mut gpu_fc_routed = 0usize;
        for fam in Family::ALL {
            for permute in [false, true] {
                // n=3000 pushes the denser families over the router's
                // 20k-edge floor so the "-FC" GPU default is genuinely
                // exercised, while the sparse ones still land on pfp/dfs
                let g = fam.generate(3000, 19);
                let g = if permute { crate::graph::random_permute(&g, 23) } else { g };
                let want = reference_max_cardinality(&g);
                let spec = route_graph(&g);
                if spec.is_gpu() {
                    gpu_fc_routed += 1;
                }
                let algo = crate::coordinator::registry::build(&spec, None)
                    .unwrap_or_else(|| panic!("routed spec {spec} not buildable"));
                let r = algo.run_detached(&g, Matching::empty(g.nr, g.nc));
                r.matching
                    .certify(&g)
                    .unwrap_or_else(|e| panic!("{spec} on {} permute={permute}: {e}", fam.name()));
                assert_eq!(
                    r.matching.cardinality(),
                    want,
                    "{spec} on {} permute={permute}",
                    fam.name()
                );
            }
        }
        assert!(gpu_fc_routed > 0, "at least one instance must exercise the -FC GPU default");
    }

    #[test]
    fn prop_auto_routed_reaches_reference_on_random_graphs() {
        use crate::matching::{reference_max_cardinality, Matching};
        use crate::util::qcheck::{arb_bipartite, forall, Config};
        forall(Config::cases(24), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = crate::graph::from_edges(nr, nc, &edges);
            let want = reference_max_cardinality(&g);
            let spec = route_graph(&g);
            let algo = crate::coordinator::registry::build(&spec, None)
                .ok_or_else(|| format!("routed spec {spec} not buildable"))?;
            let r = algo.run_detached(&g, Matching::empty(nr, nc));
            r.matching.certify(&g).map_err(|e| format!("{spec}: {e}"))?;
            if r.matching.cardinality() != want {
                return Err(format!("{spec}: {} != {want}", r.matching.cardinality()));
            }
            Ok(())
        });
    }

    #[test]
    fn fullscan_runs_report_zero_frontier_stats() {
        // regression: the worklist counters must stay untouched when the
        // FullScan variants run — the compacted path must not leak its
        // bookkeeping into the paper-faithful mode
        use crate::matching::Matching;
        let g = Family::Road.generate(1200, 3);
        for name in ["gpu:APFB-GPUBFS-WR-CT", "gpu:APsB-GPUBFS-MT"] {
            let algo = crate::coordinator::registry::build_named(name, None).unwrap();
            let r = algo.run_detached(&g, Matching::empty(g.nr, g.nc));
            assert_eq!(r.stats.frontier_peak, 0, "{name}");
            assert_eq!(r.stats.frontier_total, 0, "{name}");
            assert_eq!(r.stats.endpoints_total, 0, "{name}");
        }
    }
}
