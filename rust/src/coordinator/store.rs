//! Server-side graph store: named [`DynamicGraph`] handles with a cached
//! matching and per-graph statistics. This is what makes the incremental
//! subsystem reachable over the wire — `LOAD` installs a graph once,
//! `UPDATE` ships [`crate::dynamic::DeltaBatch`]es against it, `MATCH`
//! re-serves the cached maximum in one quiet phase, `DROP` evicts.
//!
//! Locking is two-level: a short-lived map lock resolves names to
//! entries, and each entry carries its own mutex held for the duration of
//! an update's apply + repair — so long repairs on one graph never block
//! traffic on another, and updates to one graph serialize (the matching
//! cache is only meaningful under per-graph ordering). Debug builds
//! assert the acquisition order (entry → recency → map) through
//! [`crate::sanitize::lockorder`].

use crate::dynamic::{DeltaBatch, DynamicGraph};
use crate::graph::csr::BipartiteCsr;
use crate::matching::Matching;
use crate::sanitize::lockorder::{self, LockClass};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// The maintained *maximum* matching for one stored graph, keyed to the
/// graph version it was computed against (a stale version is never served
/// as a warm start — `UPDATE` is the only path that advances both
/// together, and interrupted updates roll back rather than cache partial
/// results, so a cached matching is always a completed maximum).
#[derive(Debug, Clone)]
pub struct CachedMatching {
    pub matching: Matching,
    /// `DynamicGraph::version` at computation time
    pub version: u64,
}

/// Per-graph lifetime counters, reported by the server's `STATS`-adjacent
/// update replies, the `STATS graph=<name>` breakdown, and the `METRICS`
/// per-graph families; asserted by the e2e tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GraphStats {
    pub updates: u64,
    pub edges_inserted: u64,
    pub edges_deleted: u64,
    pub cols_added: u64,
    pub rows_added: u64,
    pub repairs: u64,
    /// `MATCH name=…` jobs served against this graph
    pub matches: u64,
    /// solves run from scratch (cold or stale cache — the complement of
    /// `repairs` in the repair-vs-recompute split)
    pub recomputes: u64,
    /// WAL frames fsync'd for this graph (LOAD/DROP markers + updates)
    pub wal_appends: u64,
    /// snapshot files written for this graph
    pub snapshots: u64,
}

/// One stored graph: overlay graph + cached matching + stats.
#[derive(Debug)]
pub struct StoreEntry {
    pub graph: DynamicGraph,
    pub matching: Option<CachedMatching>,
    pub stats: GraphStats,
}

/// One consistent read of a stored graph for a `MATCH name=…`: the entry
/// handle (so a successful result can be written back through the exact
/// incarnation the snapshot came from, version-guarded), the live CSR,
/// its version, and the warm-start matching (withheld when stale).
pub struct MatchView {
    pub entry: Arc<Mutex<StoreEntry>>,
    pub graph: Arc<BipartiteCsr>,
    pub version: u64,
    pub cached: Option<CachedMatching>,
}

/// Name → entry map. Cheap to clone the handles out of; see module docs
/// for the locking discipline.
#[derive(Default)]
pub struct GraphStore {
    inner: Mutex<HashMap<String, Arc<Mutex<StoreEntry>>>>,
    /// every `load` takes a fresh 2^32-wide version range, so two
    /// incarnations of the same name can never present the same graph
    /// version (the guard [`GraphStore::cache_into`] relies on)
    next_version_base: std::sync::atomic::AtomicU64,
    /// LRU bookkeeping for the optional `--max-graphs` cap: a logical
    /// clock stamped on every load/lookup; [`GraphStore::lru_victim`]
    /// picks the stalest name when the executor must evict
    clock: std::sync::atomic::AtomicU64,
    recency: Mutex<HashMap<String, u64>>,
}

impl GraphStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn touch(&self, name: &str) {
        let t = self.clock.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        lockorder::lock(LockClass::Recency, &self.recency).insert(name.to_string(), t);
    }

    /// Reserve a fresh 2^32-wide version range. Split out of
    /// [`GraphStore::load`] so the durability layer can persist the base
    /// *before* the graph becomes visible in the store.
    pub fn allocate_version_base(&self) -> u64 {
        self.next_version_base
            .fetch_add(1 << 32, std::sync::atomic::Ordering::Relaxed)
    }

    /// Advance the allocator past `seen_version`'s range — recovery calls
    /// this with every recovered graph's version so post-restart `LOAD`s
    /// can never collide with ranges already on disk.
    pub fn reserve_past(&self, seen_version: u64) {
        let min_base = ((seen_version >> 32) + 1) << 32;
        self.next_version_base
            .fetch_max(min_base, std::sync::atomic::Ordering::Relaxed);
    }

    /// Install (or replace) a named graph. Replacement discards the old
    /// entry wholesale — cached matching and stats included — because a
    /// re-`LOAD` is a new graph, not an update. Returns whether a
    /// previous entry was replaced.
    pub fn load(&self, name: &str, g: Arc<BipartiteCsr>) -> bool {
        let base = self.allocate_version_base();
        self.load_with_base(name, g, base)
    }

    /// [`GraphStore::load`] with a caller-reserved version base (from
    /// [`GraphStore::allocate_version_base`]).
    pub fn load_with_base(&self, name: &str, g: Arc<BipartiteCsr>, base: u64) -> bool {
        let entry = Arc::new(Mutex::new(StoreEntry {
            graph: DynamicGraph::from_arc(g).with_version_base(base),
            matching: None,
            stats: GraphStats::default(),
        }));
        self.touch(name);
        let mut map = lockorder::lock(LockClass::StoreMap, &self.inner);
        map.insert(name.to_string(), entry).is_some()
    }

    /// Install a recovered graph verbatim — version, overlay, and cached
    /// matching as reconstructed from disk — and fence the version
    /// allocator past its range.
    pub fn install(
        &self,
        name: &str,
        graph: DynamicGraph,
        matching: Option<CachedMatching>,
    ) -> Arc<Mutex<StoreEntry>> {
        self.reserve_past(graph.version());
        let entry = Arc::new(Mutex::new(StoreEntry {
            graph,
            matching,
            stats: GraphStats::default(),
        }));
        self.touch(name);
        lockorder::lock(LockClass::StoreMap, &self.inner).insert(name.to_string(), entry.clone());
        entry
    }

    /// Remove a named graph. Returns whether it existed.
    pub fn drop_graph(&self, name: &str) -> bool {
        lockorder::lock(LockClass::Recency, &self.recency).remove(name);
        lockorder::lock(LockClass::StoreMap, &self.inner).remove(name).is_some()
    }

    /// The least-recently-used name other than `exclude` (the graph a
    /// `LOAD` just installed must not evict itself).
    pub fn lru_victim(&self, exclude: &str) -> Option<String> {
        let recency = lockorder::lock(LockClass::Recency, &self.recency);
        lockorder::lock(LockClass::StoreMap, &self.inner)
            .keys()
            .filter(|n| n.as_str() != exclude)
            .min_by_key(|n| recency.get(*n).copied().unwrap_or(0))
            .cloned()
    }

    /// The entry handle for `name` (callers lock it themselves — the
    /// executor's `UPDATE` path holds it across apply + repair).
    pub fn entry(&self, name: &str) -> Option<Arc<Mutex<StoreEntry>>> {
        let e = lockorder::lock(LockClass::StoreMap, &self.inner).get(name).cloned();
        if e.is_some() {
            self.touch(name);
        }
        e
    }

    /// Everything a `MATCH name=…` needs, under one short entry lock —
    /// see [`MatchView`]. A matching cached against any *other* version
    /// is withheld — it may reference edges that no longer exist
    /// (`UPDATE` is the only flow that advances the graph, and it
    /// re-caches in the same lock, so in practice the versions only
    /// diverge if an entry is mutated by hand).
    pub fn graph_for_match(&self, name: &str) -> Option<MatchView> {
        let entry = self.entry(name)?;
        let (graph, version, cached) = {
            let mut e = lockorder::lock(LockClass::Entry, &entry);
            let g = e.graph.snapshot();
            let version = e.graph.version();
            let cached = e.matching.clone().filter(|c| c.version == version);
            (g, version, cached)
        };
        Some(MatchView { entry, graph, version, cached })
    }

    /// Write a freshly computed maximum back as `entry`'s cache — only if
    /// the graph hasn't moved since `version` was read (a concurrent
    /// `UPDATE` wins; its repaired matching is the newer truth). Takes the
    /// entry *handle*, never a name: re-resolving by name could hand a
    /// racing re-`LOAD`'s fresh incarnation a matching computed on a graph
    /// it never held (the version ranges are disjoint, so that write would
    /// be rejected anyway — but writing through the handle makes the
    /// target unambiguous: an orphaned entry absorbs the write harmlessly).
    pub fn cache_into(entry: &Arc<Mutex<StoreEntry>>, matching: Matching, version: u64) {
        let mut e = lockorder::lock(LockClass::Entry, entry);
        if e.graph.version() == version {
            e.matching = Some(CachedMatching { matching, version });
        }
    }

    pub fn len(&self) -> usize {
        lockorder::lock(LockClass::StoreMap, &self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One graph's counters, current version, and cached cardinality (if
    /// a fresh cache is held) under one short entry lock — the payload of
    /// the server's `STATS graph=<name>` breakdown.
    pub fn graph_stats(&self, name: &str) -> Option<(GraphStats, u64, Option<usize>)> {
        let entry = self.entry(name)?;
        let e = lockorder::lock(LockClass::Entry, &entry);
        let version = e.graph.version();
        let cached = e
            .matching
            .as_ref()
            .filter(|c| c.version == version)
            .map(|c| c.matching.cardinality());
        Some((e.stats, version, cached))
    }

    /// Counters for every stored graph, name-sorted (the `METRICS`
    /// per-graph families). Handles are collected under the map lock and
    /// each entry locked afterwards, preserving the entry → map order.
    pub fn all_graph_stats(&self) -> Vec<(String, GraphStats)> {
        let handles: Vec<(String, Arc<Mutex<StoreEntry>>)> = {
            let map = lockorder::lock(LockClass::StoreMap, &self.inner);
            map.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut v: Vec<(String, GraphStats)> = handles
            .into_iter()
            .map(|(name, h)| {
                let stats = lockorder::lock(LockClass::Entry, &h).stats;
                (name, stats)
            })
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Stored graph names, sorted (for `GRAPHS`-style listings and tests).
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            lockorder::lock(LockClass::StoreMap, &self.inner).keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;

    fn g22() -> Arc<BipartiteCsr> {
        Arc::new(from_edges(2, 2, &[(0, 0), (1, 1)]))
    }

    #[test]
    fn load_match_drop_lifecycle() {
        let store = GraphStore::new();
        assert!(store.is_empty());
        assert!(!store.load("a", g22()), "first load is not a replacement");
        let v_first = store.graph_for_match("a").unwrap().version;
        assert!(store.load("a", g22()), "second load replaces");
        assert_eq!(store.len(), 1);
        assert_eq!(store.names(), vec!["a".to_string()]);
        let view = store.graph_for_match("a").unwrap();
        let (g, version, cached) = (view.graph, view.version, view.cached);
        assert_eq!((g.nr, g.nc), (2, 2));
        assert_ne!(
            version, v_first,
            "every incarnation must live in its own version range"
        );
        assert!(cached.is_none());
        assert!(store.drop_graph("a"));
        assert!(!store.drop_graph("a"));
        assert!(store.graph_for_match("a").is_none());
        assert!(store.entry("nope").is_none());
    }

    #[test]
    fn cache_into_is_version_guarded() {
        let store = GraphStore::new();
        store.load("g", g22());
        let entry = store.entry("g").unwrap();
        let v0 = entry.lock().unwrap().graph.version();
        let m = Matching::from_cmatch(2, vec![0, 1]);
        GraphStore::cache_into(&entry, m.clone(), v0);
        let cached = store.graph_for_match("g").unwrap().cached.unwrap();
        assert_eq!(cached.matching, m);
        // a write against a stale version must be ignored
        entry
            .lock()
            .unwrap()
            .graph
            .apply(&crate::dynamic::DeltaBatch::new().delete(0, 0));
        GraphStore::cache_into(&entry, Matching::empty(2, 2), v0);
        let view = store.graph_for_match("g").unwrap();
        assert_eq!(view.version, v0 + 1);
        assert!(
            view.cached.is_none(),
            "a matching cached for an older graph version must be withheld"
        );
        // replacement clears the cache
        store.load("g", g22());
        assert!(store.graph_for_match("g").unwrap().cached.is_none());
    }

    #[test]
    fn lru_victim_tracks_recency_and_install_fences_versions() {
        let store = GraphStore::new();
        store.load("a", g22());
        store.load("b", g22());
        store.load("c", g22());
        // stalest is "a"; touching it (a lookup) moves it to the front
        assert_eq!(store.lru_victim("").as_deref(), Some("a"));
        let _ = store.entry("a");
        assert_eq!(store.lru_victim("").as_deref(), Some("b"));
        // the just-installed graph is never its own victim
        assert_eq!(store.lru_victim("b").as_deref(), Some("c"));
        // install (recovery path) fences the version allocator: the next
        // load's range must be disjoint from the recovered version's
        let recovered_version = (7u64 << 32) + 3;
        let g = DynamicGraph::from_arc(g22()).with_version_base(recovered_version);
        store.install("r", g, None);
        assert_eq!(store.graph_for_match("r").unwrap().version, recovered_version);
        store.load("fresh", g22());
        let v = store.graph_for_match("fresh").unwrap().version;
        assert!(
            v >> 32 > 7,
            "post-recovery loads must allocate past every recovered range, got {v:#x}"
        );
    }

    #[test]
    fn reload_cannot_be_poisoned_by_the_old_incarnations_matching() {
        // regression: version 0 used to recur on every re-LOAD, so a
        // MATCH racing a re-LOAD could cache the OLD graph's matching as
        // the NEW graph's warm start — version ranges are now disjoint,
        // and write-backs go through the entry handle captured at read
        // time, so a racing writer's result lands on the orphan
        let store = GraphStore::new();
        store.load("g", g22());
        let old_entry = store.entry("g").unwrap();
        let v_old = old_entry.lock().unwrap().graph.version();
        store.load("g", g22());
        GraphStore::cache_into(&old_entry, Matching::from_cmatch(2, vec![0, 1]), v_old);
        let view = store.graph_for_match("g").unwrap();
        assert_ne!(v_old, view.version);
        assert!(
            view.cached.is_none(),
            "a write-back against the old incarnation must not reach the new one"
        );
    }
}
