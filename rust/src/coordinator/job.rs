//! Job and result types for the matching service.

use crate::graph::csr::BipartiteCsr;
use crate::graph::gen::Family;
use crate::matching::init::InitHeuristic;
use std::sync::Arc;

/// Where the job's graph comes from.
#[derive(Debug, Clone)]
pub enum GraphSource {
    /// synthetic: family, n, seed, permuted?
    Generate { family: Family, n: usize, seed: u64, permute: bool },
    /// a MatrixMarket file on disk
    MtxFile(String),
    /// an already-built graph (in-process callers)
    InMemory(Arc<BipartiteCsr>),
}

/// Which matcher to use.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AlgoChoice {
    /// let the router pick based on graph features
    Auto,
    /// a registry name, e.g. "hk", "pfp", "gpu:APFB-GPUBFS-WR-CT",
    /// "xla:apfb-full"
    Named(String),
}

/// One matching request.
#[derive(Debug, Clone)]
pub struct MatchJob {
    pub id: u64,
    pub source: GraphSource,
    pub algo: AlgoChoice,
    pub init: InitHeuristic,
    /// verify validity+maximality before reporting (costs one BFS)
    pub certify: bool,
    /// frontier-mode override applied *after* routing: when the resolved
    /// algorithm is a `gpu:*` variant, its "-FC" suffix is normalized to
    /// this mode; CPU picks (pfp/dfs/...) are left untouched. `None`
    /// keeps whatever the router or the caller named.
    pub frontier: Option<crate::gpu::FrontierMode>,
}

impl MatchJob {
    pub fn new(id: u64, source: GraphSource) -> Self {
        Self {
            id,
            source,
            algo: AlgoChoice::Auto,
            init: InitHeuristic::Cheap,
            certify: true,
            frontier: None,
        }
    }

    pub fn with_algo(mut self, name: &str) -> Self {
        self.algo = AlgoChoice::Named(name.to_string());
        self
    }

    pub fn with_frontier(mut self, mode: crate::gpu::FrontierMode) -> Self {
        self.frontier = Some(mode);
        self
    }
}

/// Outcome of one job.
#[derive(Debug, Clone)]
pub struct MatchOutcome {
    pub job_id: u64,
    pub algo: String,
    pub nr: usize,
    pub nc: usize,
    pub n_edges: usize,
    pub cardinality: usize,
    pub init_cardinality: usize,
    pub certified: bool,
    /// seconds: graph acquisition, init heuristic, matching, total
    pub t_load: f64,
    pub t_init: f64,
    pub t_match: f64,
    pub phases: u64,
    pub error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_builder() {
        let j = MatchJob::new(
            7,
            GraphSource::Generate { family: Family::Kron, n: 100, seed: 1, permute: false },
        )
        .with_algo("hk");
        assert_eq!(j.id, 7);
        assert_eq!(j.algo, AlgoChoice::Named("hk".into()));
        assert!(j.certify);
    }
}
