//! Job and result types for the matching service.

use super::spec::AlgoSpec;
use crate::dynamic::DeltaBatch;
use crate::graph::csr::BipartiteCsr;
use crate::graph::gen::Family;
use crate::matching::init::InitHeuristic;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Where the job's graph comes from.
#[derive(Debug, Clone)]
pub enum GraphSource {
    /// synthetic: family, n, seed, permuted?
    Generate { family: Family, n: usize, seed: u64, permute: bool },
    /// a MatrixMarket file on disk
    MtxFile(String),
    /// an already-built graph (in-process callers)
    InMemory(Arc<BipartiteCsr>),
    /// a named graph held by the executor's
    /// [`super::store::GraphStore`] (`LOAD` it first)
    Stored(String),
}

/// What the job does. `Match` is the classic one-shot request; the other
/// three are the incremental-subsystem verbs, routed through the same
/// executor so metrics, deadlines, and cancellation apply uniformly.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub enum JobOp {
    /// compute a maximum matching of the job's graph
    #[default]
    Match,
    /// install the job's graph into the store under `name`
    Load { name: String },
    /// apply a delta batch to stored graph `name` and repair its matching
    Update { name: String, batch: DeltaBatch },
    /// evict stored graph `name`
    DropGraph { name: String },
    /// force a durable snapshot (+ WAL compaction) of stored graph
    /// `name` — requires the executor to run with a data dir
    Save { name: String },
}

/// Which matcher to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoChoice {
    /// let the router pick based on graph features
    Auto,
    /// a typed spec, e.g. parsed from "hk", "p-dbfs@4",
    /// "gpu:APFB-GPUBFS-WR-CT", "xla:apfb-full"
    Spec(AlgoSpec),
}

/// One matching request.
#[derive(Debug, Clone)]
pub struct MatchJob {
    pub id: u64,
    /// what to do (default [`JobOp::Match`])
    pub op: JobOp,
    pub source: GraphSource,
    pub algo: AlgoChoice,
    pub init: InitHeuristic,
    /// verify validity+maximality before reporting (costs one BFS)
    pub certify: bool,
    /// frontier-mode override applied *after* routing: when the resolved
    /// spec is a GPU variant, its `frontier` field is set to this mode
    /// (a typed edit — see `AlgoSpec::set_frontier`); CPU picks
    /// (pfp/dfs/...) are left untouched. `None` keeps whatever the router
    /// or the caller specified.
    pub frontier: Option<crate::gpu::FrontierMode>,
    /// overall deadline measured from the start of execution (graph
    /// acquisition included). A job that trips it fails with
    /// [`JobError::DeadlineExceeded`] instead of serving a possibly
    /// non-maximum matching.
    pub timeout: Option<Duration>,
    /// absolute deadline (batch-wide budgets — see
    /// `Service::run_batch_with_timeout_ms`); when both this and
    /// `timeout` are set the earlier instant wins.
    pub deadline: Option<Instant>,
    /// when the job entered the submission queue. Set by
    /// `Service::submit` so a tracing executor can backdate the span
    /// timeline and expose the queue wait as a `queue_wait` span; `None`
    /// (direct `Executor::execute` callers) means no queue to measure.
    pub submitted_at: Option<Instant>,
}

impl MatchJob {
    pub fn new(id: u64, source: GraphSource) -> Self {
        Self {
            id,
            op: JobOp::Match,
            source,
            algo: AlgoChoice::Auto,
            init: InitHeuristic::Cheap,
            certify: true,
            frontier: None,
            timeout: None,
            deadline: None,
            submitted_at: None,
        }
    }

    /// A `LOAD`: acquire the graph from `source` and store it as `name`.
    pub fn load_graph(id: u64, name: impl Into<String>, source: GraphSource) -> Self {
        let mut j = Self::new(id, source);
        j.op = JobOp::Load { name: name.into() };
        j
    }

    /// An `UPDATE`: apply `batch` to stored graph `name` and repair.
    /// The name in `op` is authoritative — `source` is set to
    /// `Stored(name)` purely so Debug output and generic source
    /// inspection show where the graph lives; the executor reads the op.
    pub fn update_graph(id: u64, name: impl Into<String>, batch: DeltaBatch) -> Self {
        let name = name.into();
        let mut j = Self::new(id, GraphSource::Stored(name.clone()));
        j.op = JobOp::Update { name, batch };
        j
    }

    /// A `DROP`: evict stored graph `name` (as with
    /// [`MatchJob::update_graph`], the op's name is authoritative).
    pub fn drop_graph(id: u64, name: impl Into<String>) -> Self {
        let name = name.into();
        let mut j = Self::new(id, GraphSource::Stored(name.clone()));
        j.op = JobOp::DropGraph { name };
        j
    }

    /// A `SAVE`: durably snapshot stored graph `name` and compact its
    /// write-ahead log now, instead of waiting for the next threshold
    /// rebuild to piggyback on.
    pub fn save_graph(id: u64, name: impl Into<String>) -> Self {
        let name = name.into();
        let mut j = Self::new(id, GraphSource::Stored(name.clone()));
        j.op = JobOp::Save { name };
        j
    }

    /// Pick a matcher by registry name. Panics on a malformed name —
    /// parse with `AlgoSpec::from_str` first (as the server and CLI do)
    /// when the name comes from untrusted input.
    pub fn with_algo(self, name: &str) -> Self {
        let spec: AlgoSpec = name.parse().unwrap_or_else(|e| panic!("{e}"));
        self.with_spec(spec)
    }

    pub fn with_spec(mut self, spec: AlgoSpec) -> Self {
        self.algo = AlgoChoice::Spec(spec);
        self
    }

    pub fn with_frontier(mut self, mode: crate::gpu::FrontierMode) -> Self {
        self.frontier = Some(mode);
        self
    }

    pub fn with_timeout_ms(mut self, ms: u64) -> Self {
        self.timeout = Some(Duration::from_millis(ms));
        self
    }

    /// Cap the job by an absolute instant (kept if earlier than an
    /// already-set deadline).
    pub fn with_deadline_at(mut self, at: Instant) -> Self {
        self.deadline = Some(self.deadline.map_or(at, |d| d.min(at)));
        self
    }
}

/// Why a job failed — typed so callers (and the TCP protocol) can
/// distinguish a tripped deadline from a bad request or a certification
/// failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobError {
    /// graph acquisition failed (generator/mtx errors)
    Load(String),
    /// the spec is known but cannot be built (xla without artifacts)
    Unavailable(String),
    /// the run completed but its result failed certification
    Certify(String),
    /// the run tripped its deadline at an inter-phase checkpoint
    DeadlineExceeded { timeout_ms: u64 },
    /// the run observed its cancellation token
    Cancelled,
    /// the node is a read replica (or a fenced ex-primary): write verbs
    /// are rejected wholesale — `PROMOTE` it or write to the primary
    ReadOnly,
    /// the write committed locally but replication did not confirm it in
    /// time (quorum ack mode): the update is durable *here* and will
    /// reach followers when they reconnect, but the client must treat it
    /// as in-doubt until a later read confirms it
    Replication(String),
}

impl JobError {
    /// Stable short name for structured logs — the `outcome=` field of a
    /// slow-request event (a completed job logs `complete` instead).
    pub fn kind(&self) -> &'static str {
        match self {
            JobError::Load(_) => "load_failed",
            JobError::Unavailable(_) => "unavailable",
            JobError::Certify(_) => "certify_failed",
            JobError::DeadlineExceeded { .. } => "timeout",
            JobError::Cancelled => "cancelled",
            JobError::ReadOnly => "read_only",
            JobError::Replication(_) => "replication",
        }
    }
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Load(e) => write!(f, "load failed: {e}"),
            JobError::Unavailable(e) => write!(f, "algorithm unavailable: {e}"),
            JobError::Certify(e) => write!(f, "certification failed: {e}"),
            JobError::DeadlineExceeded { timeout_ms } => {
                write!(f, "timeout: exceeded the {timeout_ms} ms deadline")
            }
            JobError::Cancelled => write!(f, "cancelled"),
            JobError::ReadOnly => write!(
                f,
                "read-only: this node is a replica or fenced ex-primary \
                 (PROMOTE it or write to the primary)"
            ),
            JobError::Replication(e) => write!(f, "replication: {e}"),
        }
    }
}

/// What an [`JobOp::Update`] did, attached to its [`MatchOutcome`] so the
/// server can report the delta's effect alongside the repaired matching.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct UpdateStats {
    /// net edges inserted / deleted by the batch
    pub inserted: u64,
    pub deleted: u64,
    pub cols_added: u64,
    pub rows_added: u64,
    /// out-of-range or no-op delta elements dropped
    pub rejected: u64,
    /// columns the seeded repair phase started from
    pub seeds: u64,
    /// matched edges severed by deletions
    pub dropped: u64,
    /// insertions matched directly (both endpoints free)
    pub joined: u64,
    /// whether this batch tripped an overlay→CSR rebuild
    pub rebuilt: bool,
}

/// Outcome of one job.
#[derive(Debug, Clone)]
pub struct MatchOutcome {
    pub job_id: u64,
    pub algo: String,
    pub nr: usize,
    pub nc: usize,
    pub n_edges: usize,
    pub cardinality: usize,
    pub init_cardinality: usize,
    pub certified: bool,
    /// seconds: graph acquisition, init heuristic, matching, total
    pub t_load: f64,
    pub t_init: f64,
    pub t_match: f64,
    pub phases: u64,
    /// largest BFS frontier a compacted sweep consumed (0 under FullScan
    /// and for CPU algorithms) — lets remote clients observe compaction
    pub frontier_peak: u64,
    /// endpoint-worklist items the compacted ALTERNATE consumed
    pub endpoints_total: u64,
    /// parallel-model device cycles (0 for CPU algorithms)
    pub device_parallel_cycles: u64,
    /// simulated devices the run executed on (0 unless sharded)
    pub shards: u64,
    /// 32-bit words routed over the modeled interconnect (0 unless sharded)
    pub exchange_words: u64,
    /// frontier-exchange steps that moved traffic (0 unless sharded)
    pub exchange_steps: u64,
    /// present exactly for [`JobOp::Update`] jobs
    pub update: Option<UpdateStats>,
    pub error: Option<JobError>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SeqKind;

    #[test]
    fn job_builder() {
        let j = MatchJob::new(
            7,
            GraphSource::Generate { family: Family::Kron, n: 100, seed: 1, permute: false },
        )
        .with_algo("hk")
        .with_timeout_ms(250);
        assert_eq!(j.id, 7);
        assert_eq!(j.algo, AlgoChoice::Spec(AlgoSpec::Seq(SeqKind::Hk)));
        assert_eq!(j.timeout, Some(Duration::from_millis(250)));
        assert!(j.certify);
    }

    #[test]
    #[should_panic(expected = "unknown algorithm")]
    fn with_algo_panics_on_malformed_name() {
        let _ = MatchJob::new(
            0,
            GraphSource::Generate { family: Family::Kron, n: 10, seed: 1, permute: false },
        )
        .with_algo("no-such-algo");
    }

    #[test]
    fn op_constructors_carry_names() {
        use crate::dynamic::DeltaBatch;
        let j = MatchJob::load_graph(1, "g", GraphSource::MtxFile("/x.mtx".into()));
        assert_eq!(j.op, JobOp::Load { name: "g".into() });
        let j = MatchJob::update_graph(2, "g", DeltaBatch::new().insert(0, 0));
        assert!(matches!(&j.op, JobOp::Update { name, batch } if name == "g" && batch.len() == 1));
        assert!(matches!(&j.source, GraphSource::Stored(n) if n == "g"));
        let j = MatchJob::drop_graph(3, "g");
        assert_eq!(j.op, JobOp::DropGraph { name: "g".into() });
        assert_eq!(MatchJob::new(0, GraphSource::MtxFile("/x".into())).op, JobOp::Match);
    }

    #[test]
    fn deadline_at_keeps_the_earlier_instant() {
        let now = Instant::now();
        let later = now + Duration::from_secs(60);
        let j = MatchJob::new(0, GraphSource::MtxFile("/x".into()))
            .with_deadline_at(later)
            .with_deadline_at(now);
        assert_eq!(j.deadline, Some(now));
        let j = MatchJob::new(0, GraphSource::MtxFile("/x".into()))
            .with_deadline_at(now)
            .with_deadline_at(later);
        assert_eq!(j.deadline, Some(now), "a later cap must not loosen the deadline");
    }

    #[test]
    fn job_error_display_is_distinct() {
        let t = JobError::DeadlineExceeded { timeout_ms: 5 }.to_string();
        assert!(t.starts_with("timeout:"), "{t}");
        assert!(t.contains("5 ms"));
        assert_eq!(JobError::Cancelled.to_string(), "cancelled");
        assert!(JobError::Load("x".into()).to_string().contains("load failed"));
    }
}
