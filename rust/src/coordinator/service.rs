//! The matching service: a bounded job queue feeding a worker pool, with
//! outcomes streamed to a result queue. This is the L3 "coordinator"
//! proper — the piece a downstream system embeds.

use super::exec::Executor;
use super::job::{MatchJob, MatchOutcome};
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::store::GraphStore;
use crate::matching::algo::CancelToken;
use crate::persist::{Persistence, RecoveryReport};
use crate::runtime::Engine;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How to start a [`Service`]. The plain constructor
/// ([`Service::start`]) covers the in-memory case; the config adds the
/// durability knobs (`data_dir` → WAL + snapshots + startup recovery,
/// `max_graphs` → LRU store cap).
pub struct ServiceConfig {
    pub n_workers: usize,
    pub queue_depth: usize,
    pub engine: Option<Arc<Engine>>,
    /// directory for per-graph WALs and snapshots; `None` = volatile
    pub data_dir: Option<PathBuf>,
    /// LRU cap on in-memory stored graphs; `None` = unlimited
    pub max_graphs: Option<usize>,
    /// start in read-replica mode: every write verb (LOAD/UPDATE/DROP/
    /// SAVE) fails with `JobError::ReadOnly` while MATCH keeps serving
    pub read_only: bool,
    /// write snapshots as per-shard file sets of this size (1 = single
    /// file); recovery reads either layout regardless
    pub snapshot_shards: usize,
}

impl ServiceConfig {
    pub fn new(n_workers: usize, queue_depth: usize) -> Self {
        Self {
            n_workers,
            queue_depth,
            engine: None,
            data_dir: None,
            max_graphs: None,
            read_only: false,
            snapshot_shards: 1,
        }
    }

    pub fn engine(mut self, engine: Option<Arc<Engine>>) -> Self {
        self.engine = engine;
        self
    }

    pub fn data_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.data_dir = Some(dir.into());
        self
    }

    pub fn max_graphs(mut self, max: usize) -> Self {
        self.max_graphs = Some(max);
        self
    }

    pub fn read_only(mut self, read_only: bool) -> Self {
        self.read_only = read_only;
        self
    }

    pub fn snapshot_shards(mut self, shards: usize) -> Self {
        self.snapshot_shards = shards.max(1);
        self
    }
}

pub struct Service {
    jobs: Arc<BoundedQueue<MatchJob>>,
    results: Arc<BoundedQueue<MatchOutcome>>,
    pub metrics: Arc<Metrics>,
    cancel: CancelToken,
    store: Arc<GraphStore>,
    recovery: Option<RecoveryReport>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Start `n_workers` workers. `queue_depth` bounds in-flight jobs
    /// (submit blocks beyond it — backpressure). Workers share one
    /// executor clone-family: one workspace pool, one cancellation token.
    pub fn start(n_workers: usize, queue_depth: usize, engine: Option<Arc<Engine>>) -> Self {
        Self::start_cfg(ServiceConfig::new(n_workers, queue_depth).engine(engine))
            .expect("volatile service start cannot fail")
    }

    /// Start from a [`ServiceConfig`]. With a `data_dir`, the store is
    /// recovered from disk *before* any worker accepts a job — every
    /// surviving graph is installed at its logged version with its
    /// matching restored by seeded repair ([`Service::recovery`] reports
    /// what happened) — and all further `LOAD`/`UPDATE`/`DROP` traffic is
    /// made durable (see `crate::persist`). Errors only on an unusable
    /// data dir.
    pub fn start_cfg(cfg: ServiceConfig) -> std::io::Result<Self> {
        assert!(cfg.n_workers >= 1);
        let jobs: Arc<BoundedQueue<MatchJob>> = Arc::new(BoundedQueue::new(cfg.queue_depth));
        let results: Arc<BoundedQueue<MatchOutcome>> =
            Arc::new(BoundedQueue::new(cfg.queue_depth.max(1024)));
        let metrics = Arc::new(Metrics::new());
        let mut executor = Executor::new(cfg.engine, metrics.clone());
        if let Some(dir) = &cfg.data_dir {
            let p = Persistence::open(dir)?;
            p.set_snapshot_shards(cfg.snapshot_shards);
            executor = executor.with_persistence(Arc::new(p));
        }
        if let Some(max) = cfg.max_graphs {
            executor = executor.with_max_graphs(max);
        }
        if cfg.read_only {
            executor.set_read_only(true);
        }
        // recovery runs on the caller's thread, before traffic: a MATCH
        // submitted right after start_cfg already sees the restored store
        let recovery = if cfg.data_dir.is_some() { Some(executor.recover()?) } else { None };
        let cancel = executor.cancel_token();
        let store = executor.store().clone();
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for wid in 0..cfg.n_workers {
            let jobs = jobs.clone();
            let results = results.clone();
            let executor = executor.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bimatch-worker-{wid}"))
                    .spawn(move || {
                        while let Some(job) = jobs.pop() {
                            let outcome = executor.execute(&job);
                            // result queue closing first is fine on shutdown
                            let _ = results.push(outcome);
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Ok(Self { jobs, results, metrics, cancel, store, recovery, workers })
    }

    /// What startup recovery restored (None when started without a data
    /// dir). The e2e durability tests assert on the per-graph repair
    /// stats in here.
    pub fn recovery(&self) -> Option<&RecoveryReport> {
        self.recovery.as_ref()
    }

    /// The graph store shared by this service's workers — `LOAD`ed graphs
    /// live here across jobs (observability + tests).
    pub fn store(&self) -> &Arc<GraphStore> {
        &self.store
    }

    /// Submit a job (blocks when the queue is full). Err after shutdown.
    /// Only jobs that actually enter the queue count as submitted — a
    /// post-shutdown submit returns `Err(job)` with the counter rolled
    /// back, keeping `submitted == completed + failed` an invariant. The
    /// counter is bumped *before* the push (and undone on rejection) so a
    /// fast worker can never make `completed + failed` overtake
    /// `submitted` mid-submit. The enqueue instant is stamped on the job
    /// so a tracing executor can emit the `queue_wait` span.
    pub fn submit(&self, mut job: MatchJob) -> Result<(), MatchJob> {
        use std::sync::atomic::Ordering;
        job.submitted_at = Some(Instant::now());
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        match self.jobs.push(job) {
            Ok(()) => Ok(()),
            Err(job) => {
                self.metrics.jobs_submitted.fetch_sub(1, Ordering::Relaxed);
                Err(job)
            }
        }
    }

    /// Cancel every in-flight run (they fail with `JobError::Cancelled` at
    /// their next inter-phase checkpoint). Queued-but-unstarted jobs fail
    /// the same way when a worker picks them up — use before `shutdown`
    /// to drain a service fast without waiting out long matchings.
    pub fn cancel_inflight(&self) {
        self.cancel.cancel();
    }

    /// Blocking receive of the next outcome (None after shutdown+drain).
    pub fn recv(&self) -> Option<MatchOutcome> {
        self.results.pop()
    }

    /// Stop accepting jobs, wait for workers, close the results queue.
    /// Remaining outcomes stay poppable until drained.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.results.close();
        self.metrics.clone()
    }

    /// [`Service::run_batch`] under one *batch-wide* deadline: every job
    /// is capped by the same absolute instant, `budget_ms` from now (an
    /// already-set earlier per-job deadline is kept). Jobs that can't make
    /// the cut fail with [`super::job::JobError::DeadlineExceeded`] —
    /// the whole batch still returns, each outcome tagged.
    pub fn run_batch_with_timeout_ms(
        self,
        mut batch: Vec<MatchJob>,
        budget_ms: u64,
    ) -> (Vec<MatchOutcome>, Arc<Metrics>) {
        let deadline = Instant::now() + Duration::from_millis(budget_ms);
        for job in &mut batch {
            job.deadline = Some(job.deadline.map_or(deadline, |d| d.min(deadline)));
        }
        self.run_batch(batch)
    }

    /// Convenience: run a batch of jobs to completion, returning outcomes
    /// ordered by job id.
    pub fn run_batch(self, batch: Vec<MatchJob>) -> (Vec<MatchOutcome>, Arc<Metrics>) {
        let n = batch.len();
        for job in batch {
            self.submit(job).expect("service closed during batch");
        }
        let mut outcomes = Vec::with_capacity(n);
        while outcomes.len() < n {
            match self.recv() {
                Some(o) => outcomes.push(o),
                None => break,
            }
        }
        let metrics = self.shutdown();
        outcomes.sort_by_key(|o| o.job_id);
        (outcomes, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::GraphSource;
    use crate::graph::gen::Family;

    fn gen_job(id: u64, n: usize) -> MatchJob {
        MatchJob::new(
            id,
            GraphSource::Generate { family: Family::Uniform, n, seed: id, permute: false },
        )
    }

    #[test]
    fn batch_completes_all_jobs_in_order() {
        let svc = Service::start(2, 4, None);
        let jobs: Vec<MatchJob> = (0..10).map(|i| gen_job(i, 200)).collect();
        let (outcomes, metrics) = svc.run_batch(jobs);
        assert_eq!(outcomes.len(), 10);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.job_id, i as u64);
            assert!(o.certified, "job {i}: {:?}", o.error);
        }
        assert_eq!(metrics.completed(), 10);
    }

    #[test]
    fn mixed_algorithms_in_one_batch() {
        let svc = Service::start(3, 8, None);
        let mut jobs = vec![
            gen_job(0, 300).with_algo("hk"),
            gen_job(1, 300).with_algo("pfp"),
            gen_job(2, 300).with_algo("gpu:APFB-GPUBFS-WR-CT"),
            gen_job(3, 300).with_algo("p-dbfs"),
        ];
        jobs.push(gen_job(4, 300)); // auto
        let (outcomes, _) = svc.run_batch(jobs);
        // all must agree on cardinality (same generated graph per-seed
        // differs, so check each is certified instead)
        assert!(outcomes.iter().all(|o| o.certified));
    }

    #[test]
    fn shutdown_then_submit_fails() {
        let svc = Service::start(1, 2, None);
        let jobs_handle = svc.jobs.clone();
        let metrics = svc.shutdown();
        assert_eq!(metrics.completed(), 0);
        assert!(jobs_handle.push(gen_job(0, 10)).is_err());
    }

    #[test]
    fn rejected_submit_does_not_inflate_the_submitted_counter() {
        // regression: submit used to count BEFORE pushing, so a
        // post-shutdown submit returned Err(job) but still bumped
        // jobs_submitted, breaking submitted == completed + failed
        use std::sync::atomic::Ordering;
        let svc = Service::start(1, 2, None);
        svc.jobs.close();
        assert!(svc.submit(gen_job(0, 100)).is_err());
        assert_eq!(
            svc.metrics.jobs_submitted.load(Ordering::Relaxed),
            0,
            "a rejected submit must not count as submitted"
        );
        let metrics = svc.shutdown();
        assert_eq!(metrics.jobs_submitted.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.completed() + metrics.jobs_failed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn errors_are_reported_not_dropped() {
        let svc = Service::start(1, 2, None);
        // an xla job without an engine fails at build time
        let (outcomes, _) = svc.run_batch(vec![gen_job(0, 100).with_algo("xla:apfb-full")]);
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].error.is_some());
    }

    #[test]
    fn batch_wide_deadline_trips_as_deadline_exceeded() {
        // ROADMAP follow-up regression: a batch-wide budget of zero must
        // fail every job with the distinct DeadlineExceeded error (not
        // Cancelled, not a silently suboptimal answer) and count each
        // under jobs_timed_out
        use crate::coordinator::job::JobError;
        use std::sync::atomic::Ordering;
        let svc = Service::start(2, 8, None);
        let jobs: Vec<MatchJob> = (0..4).map(|i| gen_job(i, 600)).collect();
        let (outcomes, metrics) = svc.run_batch_with_timeout_ms(jobs, 0);
        assert_eq!(outcomes.len(), 4);
        for o in &outcomes {
            assert!(
                matches!(o.error, Some(JobError::DeadlineExceeded { .. })),
                "job {}: {:?}",
                o.job_id,
                o.error
            );
            assert!(!o.certified);
        }
        assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 4);
        assert_eq!(
            metrics.jobs_submitted.load(Ordering::Relaxed),
            metrics.completed() + metrics.jobs_failed.load(Ordering::Relaxed)
        );
        // a generous batch budget does not interfere
        let svc = Service::start(2, 8, None);
        let (outcomes, metrics) =
            svc.run_batch_with_timeout_ms((0..3).map(|i| gen_job(i, 300)).collect(), 120_000);
        assert!(outcomes.iter().all(|o| o.error.is_none()), "{outcomes:?}");
        assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn stored_graphs_flow_through_the_worker_pool() {
        // LOAD → MATCH → UPDATE → MATCH → DROP as queued jobs: the store
        // is shared by every worker's executor clone. One worker keeps the
        // verbs ordered (with several, a MATCH could race ahead of its
        // LOAD — callers sequence dependent verbs themselves).
        use crate::coordinator::job::{GraphSource, MatchJob};
        use crate::dynamic::DeltaBatch;
        use std::sync::atomic::Ordering;
        let svc = Service::start(1, 8, None);
        let jobs = vec![
            MatchJob::load_graph(
                0,
                "t",
                GraphSource::Generate { family: Family::Uniform, n: 300, seed: 5, permute: false },
            ),
            MatchJob::new(1, GraphSource::Stored("t".into())),
            MatchJob::update_graph(2, "t", DeltaBatch::new().add_column(vec![0, 1, 2])),
            MatchJob::new(3, GraphSource::Stored("t".into())),
            MatchJob::drop_graph(4, "t"),
        ];
        assert!(svc.store().is_empty());
        let (outcomes, metrics) = svc.run_batch(jobs);
        assert_eq!(outcomes.len(), 5);
        for o in &outcomes {
            assert!(o.error.is_none(), "job {}: {:?}", o.job_id, o.error);
        }
        assert!(outcomes[1].certified && outcomes[3].certified);
        assert_eq!(metrics.jobs_updated.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.graphs_loaded.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.graphs_dropped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn read_only_service_rejects_writes_but_serves_matches() {
        use crate::coordinator::job::JobError;
        let svc = Service::start_cfg(ServiceConfig::new(1, 8).read_only(true)).unwrap();
        let jobs = vec![
            // one-shot MATCH still flows on a replica
            gen_job(0, 200),
            // every write verb bounces with the typed ReadOnly error
            MatchJob::load_graph(
                1,
                "t",
                GraphSource::Generate { family: Family::Uniform, n: 100, seed: 1, permute: false },
            ),
            MatchJob::drop_graph(2, "t"),
        ];
        let (outcomes, _) = svc.run_batch(jobs);
        assert!(outcomes[0].error.is_none(), "{:?}", outcomes[0].error);
        assert!(outcomes[0].certified);
        assert_eq!(outcomes[1].error, Some(JobError::ReadOnly));
        assert_eq!(outcomes[2].error, Some(JobError::ReadOnly));
    }

    #[test]
    fn cancel_inflight_fails_jobs_as_cancelled() {
        use crate::coordinator::job::JobError;
        let svc = Service::start(2, 8, None);
        svc.cancel_inflight();
        let (outcomes, metrics) = svc.run_batch((0..4).map(|i| gen_job(i, 400)).collect());
        assert_eq!(outcomes.len(), 4);
        for o in &outcomes {
            assert_eq!(o.error, Some(JobError::Cancelled), "job {}", o.job_id);
        }
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.jobs_cancelled.load(Ordering::Relaxed), 4);
        assert_eq!(
            metrics.jobs_submitted.load(Ordering::Relaxed),
            metrics.completed() + metrics.jobs_failed.load(Ordering::Relaxed)
        );
    }
}
