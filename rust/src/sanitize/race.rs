//! Device race sanitizer: TSan-style shadow logging for the modeled GPU.
//!
//! The paper's fastest kernels are *deliberately* racy — GPUBFS/GPUBFS-WR
//! claim BFS levels and endpoint rows through compare-and-swap, and the
//! correctness argument is that **any** interleaving of CAS claims still
//! yields a maximal matching (FIXMATCHING plus the driver's safety net
//! absorb every arbitration). That argument only covers accesses that go
//! through the atomic substrate: a same-cell conflict between *plain*
//! (non-atomic) accesses from two modeled threads is a bug in the kernel,
//! full stop — on real hardware it is an undefined-behaviour data race,
//! and on the host-parallel simulator it is one too (the
//! [`crate::util::pool::SharedSlice`] escape hatch has no synchronization).
//!
//! This module checks that boundary. When enabled (`BIMATCH_SANITIZE=1`
//! or a test-scoped [`ScopedEnable`]), every `SharedSlice::set/get/get_mut`
//! and every [`crate::util::pool::AtomicCells`] operation executed inside
//! a parallel launch is recorded as `(modeled item, cell, access kind)`
//! into per-launch shadow state; at launch end [`LaunchShadow::finish`]
//! flags any same-cell pair from *distinct modeled items* where at least
//! one side is a write and the two sides did not both go through the
//! atomic substrate. Atomic-vs-atomic conflicts (CAS claims, the racy
//! GPUBFS-WR endpoint store) are the paper's sanctioned races and pass.
//!
//! Two extra checks ride along:
//! * **Lane domain** — per-host-thread output buffers (the frontier
//!   kernels' `FrontierBufs`) are written via
//!   `SharedSlice::get_lane_mut`, which logs under the *host lane* id
//!   instead of the modeled item: many items on one lane legitimately
//!   share the slot, but two lanes touching the same slot is a bug.
//! * **Cost cross-check** — every shadow-logged atomic RMW (cas/swap)
//!   must be matched by a `CAS_COST` charge in the launch's per-item work
//!   record, so an undercharged kernel (atomics the modeled clock never
//!   saw) fails loudly instead of quietly flattering the paper tables.
//!
//! Everything here is zero-cost when disabled: the hooks are a single
//! relaxed atomic load, no shadow state is allocated, and launches carry
//! no guard objects.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex, Once};

/// Number of active enable sources: the `BIMATCH_SANITIZE=1` environment
/// contributes one (folded in once by [`init_env`]), and each live
/// [`ScopedEnable`] contributes one.
static ACTIVE: AtomicU32 = AtomicU32::new(0);
static ENV_INIT: Once = Once::new();

fn init_env() {
    ENV_INIT.call_once(|| {
        let on = std::env::var("BIMATCH_SANITIZE").map(|v| v == "1").unwrap_or(false);
        if on {
            ACTIVE.fetch_add(1, Ordering::Relaxed);
        }
    });
}

/// The fast-path gate the access hooks check: one relaxed load. `true`
/// only after [`init_env`] ran (any launch scope or [`ScopedEnable`]
/// does) or a [`ScopedEnable`] is live — before that, hooks are no-ops,
/// which is fine because no shadow state exists to record into either.
#[inline(always)]
fn armed() -> bool {
    ACTIVE.load(Ordering::Relaxed) != 0
}

/// Whether the sanitizer is enabled for launches started now.
pub fn enabled() -> bool {
    init_env();
    armed()
}

/// RAII enable for tests: bumps the global enable count on creation and
/// drops it on `Drop`, so a test can sanitize its launches without
/// touching the environment (and without affecting parallel tests, whose
/// clean kernels simply get checked too).
#[derive(Debug)]
pub struct ScopedEnable(());

impl ScopedEnable {
    pub fn new() -> Self {
        init_env();
        ACTIVE.fetch_add(1, Ordering::Relaxed);
        ScopedEnable(())
    }
}

impl Default for ScopedEnable {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for ScopedEnable {
    fn drop(&mut self) {
        ACTIVE.fetch_sub(1, Ordering::Relaxed);
    }
}

/// One shadow-logged access kind. `Lane*` kinds live in a separate
/// conflict domain keyed by host-thread lane instead of modeled item.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    /// `SharedSlice::get` — plain read
    NaRead,
    /// `SharedSlice::set` / `get_mut` — plain write
    NaWrite,
    /// `SharedSlice::get_lane_mut` — plain write keyed by host lane
    LaneWrite,
    /// `AtomicCells::load`
    AtomicRead,
    /// `AtomicCells::store`
    AtomicWrite,
    /// `AtomicCells::cas` / `swap` — must be matched by a `CAS_COST` charge
    AtomicRmw,
}

#[derive(Clone, Copy)]
struct Access {
    cell: usize,
    /// modeled item index, or host lane for [`AccessKind::LaneWrite`]
    who: u32,
    kind: AccessKind,
}

struct ThreadCtx {
    shadow: Arc<LaunchShadow>,
    log: Vec<Access>,
    item: u32,
    lane: u32,
}

thread_local! {
    static CTX: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

/// Shadow state for one parallel launch. Created by
/// [`launch_scope`] (when enabled), fed by per-thread guards, and
/// consumed by [`LaunchShadow::finish`] after the join.
pub struct LaunchShadow {
    kernel: &'static str,
    log: Mutex<Vec<Access>>,
}

/// Start shadowing a parallel launch of `kernel`. Returns `None` when
/// the sanitizer is disabled — callers thread the `Option` through so
/// the disabled path allocates nothing.
pub fn launch_scope(kernel: &'static str) -> Option<Arc<LaunchShadow>> {
    if !enabled() {
        return None;
    }
    Some(Arc::new(LaunchShadow { kernel, log: Mutex::new(Vec::new()) }))
}

/// Flushes this thread's access log into the launch shadow on drop.
pub struct ThreadGuard(());

impl LaunchShadow {
    /// Install this launch's shadow on the current worker thread (host
    /// lane `lane`). The returned guard flushes the thread-local log back
    /// into the shadow when the worker's chunk is done.
    pub fn enter(self: &Arc<Self>, lane: u32) -> ThreadGuard {
        CTX.with(|c| {
            *c.borrow_mut() = Some(ThreadCtx {
                shadow: self.clone(),
                log: Vec::new(),
                item: u32::MAX,
                lane,
            });
        });
        ThreadGuard(())
    }
}

impl Drop for ThreadGuard {
    fn drop(&mut self) {
        if let Some(ctx) = CTX.with(|c| c.borrow_mut().take()) {
            ctx.shadow.log.lock().unwrap().extend_from_slice(&ctx.log);
        }
    }
}

/// Tag subsequent accesses on this thread with the modeled item index.
/// The executors call it right before each body invocation; a no-op
/// outside an entered launch.
#[inline]
pub fn set_item(item: u32) {
    if !armed() {
        return;
    }
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            ctx.item = item;
        }
    });
}

/// The access hook `SharedSlice`/`AtomicCells` call. `addr` identifies
/// the cell (its memory address — launches never alias two live arrays).
/// No-op unless the sanitizer is armed *and* this thread is inside an
/// entered launch, so plain host-side uses (serial launches, the
/// multicore matchers) record nothing.
#[inline]
pub fn note(addr: usize, kind: AccessKind) {
    if !armed() {
        return;
    }
    note_slow(addr, kind);
}

#[cold]
fn note_slow(addr: usize, kind: AccessKind) {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            let who = if kind == AccessKind::LaneWrite { ctx.lane } else { ctx.item };
            ctx.log.push(Access { cell: addr, who, kind });
        }
    });
}

/// How [`LaunchShadow::finish`] cross-checks atomic RMW charges against
/// the cost model.
pub enum CostCheck<'a> {
    /// The racy executors' per-item work record: item `i`'s charged units
    /// are `work[i]`, and must cover `per_rmw` per logged RMW by item `i`.
    PerItem { work: &'a [u64], per_rmw: u64 },
    /// A per-item-disjoint launch: its cost formula charges no CAS at
    /// all, so *any* logged atomic RMW is an undercharge.
    Disjoint,
}

/// Up to two distinct ids — enough to answer "two distinct exist" and
/// "does an id other than `x` exist" exactly (only distinct values fill
/// the slots, so any qualifying id appears in the first two).
#[derive(Default, Clone, Copy)]
struct Items {
    a: Option<u32>,
    b: Option<u32>,
}

impl Items {
    fn add(&mut self, x: u32) {
        match (self.a, self.b) {
            (None, _) => self.a = Some(x),
            (Some(a), None) if a != x => self.b = Some(x),
            _ => {}
        }
    }

    fn pair(&self) -> Option<(u32, u32)> {
        match (self.a, self.b) {
            (Some(a), Some(b)) => Some((a, b)),
            _ => None,
        }
    }

    fn other(&self, x: u32) -> Option<u32> {
        [self.a, self.b].into_iter().flatten().find(|&v| v != x)
    }

    fn iter(&self) -> impl Iterator<Item = u32> {
        [self.a, self.b].into_iter().flatten()
    }
}

#[derive(Default, Clone, Copy)]
struct CellState {
    na_read: Items,
    na_write: Items,
    at_read: Items,
    at_write: Items,
    lane_write: Items,
}

impl LaunchShadow {
    /// End-of-launch conflict scan + cost cross-check. Panics with the
    /// kernel name and the offending modeled items on the first launch
    /// that breaks the contract; `labels` (the frontier worklist, when
    /// there is one) maps item indices to the column ids shown in the
    /// diagnostic.
    pub fn finish(self: Arc<Self>, cost: CostCheck<'_>, labels: Option<&[u32]>) {
        let log = std::mem::take(&mut *self.log.lock().unwrap());
        let mut cells: HashMap<usize, CellState> = HashMap::new();
        let mut rmw_by_item: HashMap<u32, u64> = HashMap::new();
        for a in &log {
            let st = cells.entry(a.cell).or_default();
            match a.kind {
                AccessKind::NaRead => st.na_read.add(a.who),
                AccessKind::NaWrite => st.na_write.add(a.who),
                AccessKind::LaneWrite => st.lane_write.add(a.who),
                AccessKind::AtomicRead => st.at_read.add(a.who),
                AccessKind::AtomicWrite => st.at_write.add(a.who),
                AccessKind::AtomicRmw => {
                    // an RMW is an atomic read and an atomic write
                    st.at_read.add(a.who);
                    st.at_write.add(a.who);
                    *rmw_by_item.entry(a.who).or_insert(0) += 1;
                }
            }
        }

        let label = |item: u32| -> String {
            match labels.and_then(|l| l.get(item as usize)) {
                Some(&col) => format!("item {item} (column {col})"),
                None => format!("item {item}"),
            }
        };
        let mut races: Vec<String> = Vec::new();
        for (&cell, st) in &cells {
            // plain write vs plain write
            if let Some((x, y)) = st.na_write.pair() {
                races.push(format!(
                    "non-atomic write/write on cell {cell:#x} by {} and {}",
                    label(x),
                    label(y)
                ));
                continue;
            }
            // plain write vs anything else from a distinct item: the
            // other side being atomic does not save it — both sides must
            // go through the atomic substrate to be a sanctioned race
            for w in st.na_write.iter() {
                if let Some(r) = st.na_read.other(w) {
                    races.push(format!(
                        "non-atomic write by {} races non-atomic read by {} on cell {cell:#x}",
                        label(w),
                        label(r)
                    ));
                } else if let Some(r) = st.at_read.other(w) {
                    races.push(format!(
                        "non-atomic write by {} races atomic read by {} on cell {cell:#x}",
                        label(w),
                        label(r)
                    ));
                } else if let Some(r) = st.at_write.other(w) {
                    races.push(format!(
                        "non-atomic write by {} races atomic write by {} on cell {cell:#x}",
                        label(w),
                        label(r)
                    ));
                }
            }
            // atomic write vs plain read from a distinct item
            for w in st.at_write.iter() {
                if st.na_write.iter().any(|x| x == w) {
                    continue; // already reported above for this writer
                }
                if let Some(r) = st.na_read.other(w) {
                    races.push(format!(
                        "atomic write by {} races non-atomic read by {} on cell {cell:#x}",
                        label(w),
                        label(r)
                    ));
                }
            }
            // lane domain: per-host-thread slots shared across lanes
            if let Some((x, y)) = st.lane_write.pair() {
                races.push(format!(
                    "per-lane buffer slot {cell:#x} written by host lanes {x} and {y}"
                ));
            }
        }
        if !races.is_empty() {
            races.sort();
            races.truncate(8);
            panic!(
                "device race sanitizer: kernel `{}` has {} conflicting cell(s):\n  {}",
                self.kernel,
                races.len(),
                races.join("\n  ")
            );
        }

        // cost cross-check: every logged atomic RMW must be covered by a
        // CAS_COST charge in the per-item work record
        match cost {
            CostCheck::PerItem { work, per_rmw } => {
                for (&item, &count) in &rmw_by_item {
                    let charged = work.get(item as usize).copied().unwrap_or(0);
                    let need = per_rmw * count;
                    assert!(
                        charged >= need,
                        "device race sanitizer: kernel `{}` undercharged {}: \
                         {count} atomic RMW(s) need >= {need} work units, charged {charged}",
                        self.kernel,
                        label(item),
                    );
                }
            }
            CostCheck::Disjoint => {
                if let Some((&item, &count)) = rmw_by_item.iter().next() {
                    panic!(
                        "device race sanitizer: kernel `{}` ran {count} atomic RMW(s) \
                         (e.g. by {}) under the per-item-disjoint executor, whose cost \
                         formula never charges CAS_COST",
                        self.kernel,
                        label(item),
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default_and_scoped_enable_arms() {
        // note: other tests in this binary may hold a ScopedEnable
        // concurrently, so only assert the monotone directions
        let before = ACTIVE.load(Ordering::Relaxed);
        let on = ScopedEnable::new();
        assert!(enabled());
        assert!(ACTIVE.load(Ordering::Relaxed) > before);
        drop(on);
    }

    #[test]
    fn items_tracker_answers_distinctness_exactly() {
        let mut it = Items::default();
        it.add(3);
        it.add(3);
        assert_eq!(it.pair(), None);
        assert_eq!(it.other(3), None);
        assert_eq!(it.other(9), Some(3));
        it.add(7);
        it.add(11); // third distinct id: trackers stay complete for ≠x queries
        assert_eq!(it.pair(), Some((3, 7)));
        assert_eq!(it.other(3), Some(7));
        assert_eq!(it.other(7), Some(3));
        assert_eq!(it.other(99), Some(3));
    }

    #[test]
    fn atomic_only_conflicts_are_sanctioned() {
        let _on = ScopedEnable::new();
        let shadow = launch_scope("atomic-ok").expect("enabled");
        {
            let _g = shadow.enter(0);
            set_item(1);
            note(0x1000, AccessKind::AtomicRmw);
            note(0x1000, AccessKind::AtomicWrite);
            set_item(2);
            note(0x1000, AccessKind::AtomicRmw);
            note(0x1000, AccessKind::AtomicRead);
        }
        // both items charged one CAS each
        shadow.finish(CostCheck::PerItem { work: &[0, 2, 2], per_rmw: 2 }, None);
    }

    #[test]
    #[should_panic(expected = "non-atomic write/write")]
    fn plain_write_write_is_flagged() {
        let _on = ScopedEnable::new();
        let shadow = launch_scope("ww").expect("enabled");
        {
            let _g = shadow.enter(0);
            set_item(1);
            note(0x2000, AccessKind::NaWrite);
            set_item(2);
            note(0x2000, AccessKind::NaWrite);
        }
        shadow.finish(CostCheck::Disjoint, None);
    }

    #[test]
    #[should_panic(expected = "races atomic write")]
    fn mixed_plain_and_atomic_write_is_flagged() {
        let _on = ScopedEnable::new();
        let shadow = launch_scope("mixed").expect("enabled");
        {
            let _g = shadow.enter(0);
            set_item(1);
            note(0x3000, AccessKind::NaWrite);
            set_item(2);
            note(0x3000, AccessKind::AtomicWrite);
        }
        shadow.finish(CostCheck::Disjoint, None);
    }

    #[test]
    fn same_item_reuse_and_lane_slots_are_clean() {
        let _on = ScopedEnable::new();
        let shadow = launch_scope("clean").expect("enabled");
        {
            let _g = shadow.enter(3);
            set_item(5);
            note(0x4000, AccessKind::NaWrite);
            note(0x4000, AccessKind::NaRead);
            note(0x5000, AccessKind::LaneWrite);
            set_item(6);
            note(0x5000, AccessKind::LaneWrite); // same lane, different item
        }
        shadow.finish(CostCheck::Disjoint, None);
    }

    #[test]
    #[should_panic(expected = "host lanes")]
    fn cross_lane_slot_sharing_is_flagged() {
        let _on = ScopedEnable::new();
        let shadow = launch_scope("lanes").expect("enabled");
        {
            let _g = shadow.enter(0);
            set_item(1);
            note(0x6000, AccessKind::LaneWrite);
        }
        {
            let _g = shadow.enter(1);
            set_item(2);
            note(0x6000, AccessKind::LaneWrite);
        }
        shadow.finish(CostCheck::Disjoint, None);
    }

    #[test]
    #[should_panic(expected = "undercharged")]
    fn uncharged_rmw_is_flagged() {
        let _on = ScopedEnable::new();
        let shadow = launch_scope("cheap").expect("enabled");
        {
            let _g = shadow.enter(0);
            set_item(0);
            note(0x7000, AccessKind::AtomicRmw);
        }
        shadow.finish(CostCheck::PerItem { work: &[1], per_rmw: 2 }, None);
    }
}
