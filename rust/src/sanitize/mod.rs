//! Opt-in correctness analyzers for the simulated device and the
//! serving stack. Three tools, all zero-cost when disabled:
//!
//! * [`race`] — a TSan-style **device race sanitizer** for the modeled
//!   GPU. Deveci, Kaya, Uçar & Çatalyürek build their fastest BFS
//!   kernels (GPUBFS-WR, and the `L_false` alternate/fix phases) by
//!   *deleting* atomics from the inner loops: multiple modeled threads
//!   may write the same `bfs`/`preced` cell in one launch, and
//!   correctness rests on the argument that every interleaving of those
//!   benign races still yields a maximal matching — only the
//!   augmenting-path *claims* need CAS. That argument is easy to state
//!   and easy to silently break in a refactor. Under `BIMATCH_SANITIZE=1`
//!   every [`crate::util::pool::SharedSlice`] and
//!   [`crate::util::pool::AtomicCells`] access inside a parallel launch
//!   is logged into per-launch shadow state, and launch teardown flags
//!   any same-cell conflict between distinct modeled threads that did
//!   not go through the atomic substrate — so a kernel that *means* to
//!   race must do so through `AtomicCells`, where the race is sanctioned
//!   and the cost model can see it. The same pass cross-checks cycle
//!   accounting: a kernel that performs an atomic RMW without charging
//!   `CAS_COST` is undercharging the paper-table cycle counts and gets
//!   flagged too.
//! * [`lockorder`] — a debug-build **lock-order watchdog** over the
//!   serving stack's lock families (store map, per-graph entry locks,
//!   per-name persistence locks, replication hub). It records the
//!   acquisition graph at runtime and panics on the first cycle, turning
//!   latent deadlocks into deterministic test failures.
//! * [`fsck`] — an offline **WAL/snapshot integrity checker** behind
//!   `bimatch fsck --data-dir`, replaying durability state read-only and
//!   grading findings repairable vs fatal.
//!
//! The sanitizer and watchdog are wired through `gpu/device.rs` launch
//! executors, `util/pool.rs` accessors, and the coordinator/persist lock
//! sites; with `BIMATCH_SANITIZE` unset and in release builds every hook
//! folds to a relaxed atomic load (race) or nothing at all (lockorder).

pub mod fsck;
pub mod lockorder;
pub mod race;
