//! Offline WAL/snapshot integrity checker — the static half of the
//! durability story.
//!
//! `bimatch fsck --data-dir <path>` walks a data directory the way crash
//! recovery would ([`crate::persist::recover`]) but **read-only**: it
//! never completes interrupted drops, never prunes, never rewrites.
//! For every graph name with on-disk state it verifies:
//!
//! * snapshot integrity — magic, checksum, and that the version encoded
//!   *inside* each `.snap` file matches the version in its filename;
//! * WAL frame checksums — a torn final frame (the crash signature) is
//!   *repairable* (recovery drops it and keeps the consistent prefix),
//!   anything else failing mid-log is not;
//! * incarnation scoping and version monotonicity — update frames from
//!   the anchor snapshot's incarnation must extend the version chain
//!   with no gaps, and each frame is re-applied to a scratch graph and
//!   cross-checked against its logged [`crate::dynamic::ApplyReport`]
//!   (the same [`crate::persist::apply_update_frame`] kernel recovery
//!   and replication use);
//! * snapshot↔WAL consistency — a WAL that cannot be anchored by any
//!   valid snapshot is unrecoverable and fatal.
//!
//! Findings are graded [`Severity::Info`] (harmless, e.g. stale frames
//! an incarnation switch obsoleted), [`Severity::Repairable`] (recovery
//! handles it: torn tail, pending drop, superseded snapshots), or
//! [`Severity::Fatal`] (acknowledged state would be lost: missing
//! anchor, version gap, report mismatch, corrupt newest snapshot).

use crate::dynamic::{ApplyReport, DeltaBatch, DynamicGraph};
use crate::persist::{recover, snapshot, wal, FrameStep, Persistence};
use std::io;
use std::path::Path;
use std::sync::Arc;

/// How bad one finding is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// expected/benign state worth surfacing
    Info,
    /// recovery (or the next snapshot) resolves this without data loss
    Repairable,
    /// recovery would lose acknowledged state, or cannot run at all
    Fatal,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Repairable => "repairable",
            Severity::Fatal => "FATAL",
        }
    }
}

/// One integrity finding for one graph.
#[derive(Debug, Clone)]
pub struct Finding {
    pub graph: String,
    pub severity: Severity,
    pub message: String,
}

/// Everything `fsck` found across a data dir.
#[derive(Debug, Clone, Default)]
pub struct FsckReport {
    /// graph names examined (any on-disk state)
    pub graphs: Vec<String>,
    pub findings: Vec<Finding>,
}

impl FsckReport {
    pub fn fatal_count(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Fatal).count()
    }

    pub fn repairable_count(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Repairable).count()
    }

    fn push(&mut self, graph: &str, severity: Severity, message: String) {
        self.findings.push(Finding { graph: graph.to_string(), severity, message });
    }
}

/// Check every graph in `dir`. Errors only on I/O failures scanning the
/// directory itself; per-graph problems become findings.
pub fn fsck_dir(dir: &Path) -> io::Result<FsckReport> {
    if !dir.is_dir() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("data dir {} does not exist", dir.display()),
        ));
    }
    let p = Persistence::open(dir)?;
    let mut report = FsckReport::default();
    for name in p.graph_names()? {
        fsck_graph(&p, &name, &mut report)?;
        report.graphs.push(name);
    }
    fsck_observability(dir, &mut report);
    Ok(report)
}

/// The observability artifacts a server leaves next to the durability
/// files (`events.jsonl`, `flightrec/` — see [`crate::obs`]) are known
/// residents of a data dir: surface them as info, never as orphaned or
/// damaged state.
fn fsck_observability(dir: &Path, out: &mut FsckReport) {
    let events = dir.join("events.jsonl");
    if let Ok(meta) = events.metadata() {
        out.push(
            "-",
            Severity::Info,
            format!("event log events.jsonl present ({} bytes)", meta.len()),
        );
    }
    let flightrec = dir.join("flightrec");
    if flightrec.is_dir() {
        let dumps = std::fs::read_dir(&flightrec)
            .map(|entries| {
                entries
                    .filter_map(|e| e.ok())
                    .filter(|e| {
                        e.path().extension().is_some_and(|x| x == "jsonl")
                    })
                    .count()
            })
            .unwrap_or(0);
        out.push(
            "-",
            Severity::Info,
            format!("flight recorder flightrec/ present ({dumps} dump file(s))"),
        );
    }
}

fn fsck_graph(p: &Persistence, name: &str, out: &mut FsckReport) -> io::Result<()> {
    // --- snapshots: newest-first across BOTH layouts (single-file and
    // per-shard sets), exactly the merged order recovery anchors in
    let snaps = p.snapshots_of(name);
    let shard_sets = p.shard_snapshot_sets(name);
    let mut anchor: Option<snapshot::Snapshot> = None;
    let (mut ci, mut si) = (0usize, 0usize);
    while ci < snaps.len() || si < shard_sets.len() {
        let take_combined = match (snaps.get(ci), shard_sets.get(si)) {
            (Some((cv, _)), Some((sv, _))) => cv >= sv,
            (Some(_), None) => true,
            _ => false,
        };
        if !take_combined {
            let (version, members) = &shard_sets[si];
            si += 1;
            fsck_shard_set(name, *version, members, &mut anchor, out)?;
            continue;
        }
        let (file_version, path) = &snaps[ci];
        ci += 1;
        match snapshot::read_snapshot(path)? {
            Some(s) => {
                if s.version != *file_version {
                    out.push(
                        name,
                        Severity::Fatal,
                        format!(
                            "snapshot {} declares version {} inside but {} in its filename",
                            path.display(),
                            s.version,
                            file_version
                        ),
                    );
                }
                if anchor.is_none() {
                    anchor = Some(s);
                } else {
                    out.push(
                        name,
                        Severity::Repairable,
                        format!(
                            "superseded snapshot v{file_version} still present \
                             (pruned by the next snapshot)"
                        ),
                    );
                }
            }
            None if anchor.is_none() => {
                out.push(
                    name,
                    Severity::Fatal,
                    format!(
                        "newest snapshot {} fails its checksum — recovery falls back \
                         past it and may lose acknowledged state",
                        path.display()
                    ),
                );
            }
            None => out.push(
                name,
                Severity::Repairable,
                format!(
                    "superseded snapshot {} fails its checksum (a newer valid \
                     snapshot anchors recovery)",
                    path.display()
                ),
            ),
        }
    }

    // --- WAL: checksummed frame prefix + torn-tail detection
    let (records, torn) = wal::read_wal(&p.wal_path(name))?;
    if torn {
        out.push(
            name,
            Severity::Repairable,
            "WAL ends in a torn/corrupt frame — recovery keeps the consistent prefix \
             and drops the tail"
                .to_string(),
        );
    }

    let Some(snap) = anchor else {
        // no anchor: a bare own-incarnation DROP marker is a drop that
        // recovery completes; anything else with state on disk is lost
        let only_drop = !records.is_empty()
            && records.iter().all(|r| matches!(r, wal::WalRecord::Drop { .. }));
        if only_drop && snaps.is_empty() && shard_sets.is_empty() {
            out.push(
                name,
                Severity::Repairable,
                "interrupted DROP: marker present, file deletion pending \
                 (recovery completes it)"
                    .to_string(),
            );
        } else if !records.is_empty() || !snaps.is_empty() {
            out.push(
                name,
                Severity::Fatal,
                "unrecoverable: on-disk state exists but no valid snapshot anchors \
                 the WAL replay"
                    .to_string(),
            );
        }
        return Ok(());
    };

    // --- replay walk: the same incarnation scoping / gap / report
    // cross-check as recovery, on a scratch graph (read-only on disk)
    let incarnation = snap.version >> 32;
    let floor = snap.version;
    let mut dg = DynamicGraph::from_arc(Arc::new(snap.graph)).with_version_base(floor);
    let mut skipped_stale = 0usize;
    let mut replayed = 0usize;
    for rec in records {
        match rec {
            wal::WalRecord::Load { version_base } => {
                if version_base >> 32 != incarnation {
                    skipped_stale += 1;
                }
            }
            wal::WalRecord::Drop { version } => {
                if version >> 32 == incarnation {
                    out.push(
                        name,
                        Severity::Repairable,
                        format!(
                            "DROP marker (v{version}) pending: recovery completes the \
                             interrupted file deletion"
                        ),
                    );
                    return Ok(());
                }
                skipped_stale += 1;
            }
            wal::WalRecord::Update { version_after, batch_wire, report_wire } => {
                if version_after >> 32 != incarnation || version_after <= floor {
                    skipped_stale += 1;
                    continue;
                }
                if version_after != dg.version() + 1 {
                    out.push(
                        name,
                        Severity::Fatal,
                        format!(
                            "version gap: frame v{version_after} does not extend \
                             v{} — acknowledged updates in the gap are lost",
                            dg.version()
                        ),
                    );
                    return Ok(());
                }
                if DeltaBatch::parse_wire(&batch_wire).is_err()
                    || ApplyReport::parse_wire(&report_wire).is_err()
                {
                    out.push(
                        name,
                        Severity::Fatal,
                        format!(
                            "frame v{version_after} passes its checksum but its \
                             batch/report wire does not parse — replay halts here"
                        ),
                    );
                    return Ok(());
                }
                match recover::apply_update_frame(
                    &mut dg,
                    incarnation,
                    floor,
                    version_after,
                    &batch_wire,
                    &report_wire,
                ) {
                    FrameStep::Applied(_) => replayed += 1,
                    FrameStep::Skipped => skipped_stale += 1,
                    FrameStep::Halt => {
                        out.push(
                            name,
                            Severity::Fatal,
                            format!(
                                "frame v{version_after} does not reproduce its logged \
                                 apply report — replay halts at v{}",
                                dg.version()
                            ),
                        );
                        return Ok(());
                    }
                }
            }
        }
    }
    if skipped_stale > 0 {
        out.push(
            name,
            Severity::Info,
            format!(
                "{skipped_stale} stale frame(s) from another incarnation or at/below \
                 the snapshot version (skipped by replay, removed at next compaction)"
            ),
        );
    }
    out.push(
        name,
        Severity::Info,
        format!(
            "anchor snapshot v{floor} (incarnation {incarnation}) + {replayed} \
             replayable frame(s) → recovers at v{}",
            dg.version()
        ),
    );
    Ok(())
}

/// Validate one per-shard snapshot set: every member must decode, agree
/// with its filename metadata, and the set must be complete and
/// contiguous ([`snapshot::assemble_shards`]). A set that would be the
/// newest anchor gets `Fatal` findings when damaged (recovery falls back
/// past acknowledged state); a superseded set gets `Repairable`.
fn fsck_shard_set(
    name: &str,
    version: u64,
    members: &[(u64, u64, std::path::PathBuf)],
    anchor: &mut Option<snapshot::Snapshot>,
    out: &mut FsckReport,
) -> io::Result<()> {
    let blocking = if anchor.is_none() { Severity::Fatal } else { Severity::Repairable };
    let declared_k = members.first().map(|(_, k, _)| *k).unwrap_or(0);
    let mut parts = Vec::with_capacity(members.len());
    let mut damaged = false;
    for (fshard, fshards, path) in members {
        match snapshot::read_shard_snapshot(path)? {
            Some(part) => {
                if part.version != version || part.shard != *fshard || part.shards != *fshards
                {
                    out.push(
                        name,
                        Severity::Fatal,
                        format!(
                            "shard member {} declares v{} shard {}of{} inside but \
                             v{version} shard {fshard}of{fshards} in its filename",
                            path.display(),
                            part.version,
                            part.shard,
                            part.shards
                        ),
                    );
                    damaged = true;
                } else {
                    parts.push(part);
                }
            }
            None => {
                out.push(
                    name,
                    blocking,
                    format!(
                        "shard member {} fails its checksum — the v{version} set \
                         cannot anchor recovery",
                        path.display()
                    ),
                );
                damaged = true;
            }
        }
    }
    if !damaged && parts.len() as u64 != declared_k {
        out.push(
            name,
            blocking,
            format!(
                "incomplete shard set v{version}: {}/{declared_k} members present — \
                 recovery skips the whole set",
                parts.len()
            ),
        );
        damaged = true;
    }
    if damaged {
        return Ok(());
    }
    match snapshot::assemble_shards(parts) {
        Some(s) if anchor.is_none() => {
            out.push(
                name,
                Severity::Info,
                format!("anchor is an assembled {declared_k}-shard snapshot set (v{version})"),
            );
            *anchor = Some(s);
        }
        Some(_) => out.push(
            name,
            Severity::Repairable,
            format!(
                "superseded shard set v{version} still present (pruned by the next \
                 snapshot)"
            ),
        ),
        None => out.push(
            name,
            blocking,
            format!(
                "shard set v{version} does not assemble (inconsistent or overlapping \
                 members) — recovery skips it"
            ),
        ),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use std::path::PathBuf;

    fn dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bimatch_fsck_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn seeded(tag: &str) -> (Persistence, PathBuf, DynamicGraph) {
        let d = dir(tag);
        let p = Persistence::open(&d).unwrap();
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2)]);
        let base = 4u64 << 32;
        p.record_load("g", &g, base).unwrap();
        let mut dg = DynamicGraph::new(g).with_version_base(base);
        for batch in [
            DeltaBatch::new().insert(0, 1),
            DeltaBatch::new().insert(1, 2).delete(2, 2),
        ] {
            let rep = dg.apply(&batch);
            p.append_update("g", dg.version(), &rep).unwrap();
        }
        (p, d, dg)
    }

    #[test]
    fn clean_dir_has_no_repairable_or_fatal_findings() {
        let (_p, d, dg) = seeded("clean");
        let report = fsck_dir(&d).unwrap();
        assert_eq!(report.graphs, vec!["g".to_string()]);
        assert_eq!(report.fatal_count(), 0, "{:?}", report.findings);
        assert_eq!(report.repairable_count(), 0, "{:?}", report.findings);
        let anchor_line = report
            .findings
            .iter()
            .find(|f| f.message.contains("recovers at"))
            .expect("summary finding");
        assert!(anchor_line.message.contains(&format!("v{}", dg.version())));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn torn_tail_is_repairable_not_fatal() {
        let (p, d, _dg) = seeded("torn");
        let wal_path = p.wal_path("g");
        let bytes = std::fs::read(&wal_path).unwrap();
        std::fs::write(&wal_path, &bytes[..bytes.len() - 3]).unwrap();
        let report = fsck_dir(&d).unwrap();
        assert_eq!(report.fatal_count(), 0, "{:?}", report.findings);
        assert!(report.repairable_count() >= 1);
        assert!(report.findings.iter().any(|f| f.message.contains("torn")));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_only_snapshot_is_fatal() {
        let (p, d, _dg) = seeded("rot");
        let (_, snap_path) = p.snapshots_of("g").into_iter().next().unwrap();
        let mut bytes = std::fs::read(&snap_path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&snap_path, &bytes).unwrap();
        let report = fsck_dir(&d).unwrap();
        assert!(report.fatal_count() >= 1, "{:?}", report.findings);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn version_gap_is_fatal() {
        let (p, d, mut dg) = seeded("gap");
        // forge a frame two versions ahead: a hole in the chain
        let rep = dg.apply(&DeltaBatch::new().insert(2, 0));
        wal::append(
            &p.wal_path("g"),
            &crate::persist::update_record(dg.version() + 1, &rep),
        )
        .unwrap();
        let report = fsck_dir(&d).unwrap();
        assert!(report.fatal_count() >= 1, "{:?}", report.findings);
        assert!(report.findings.iter().any(|f| f.message.contains("version gap")));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn pending_drop_marker_is_repairable_and_fsck_stays_read_only() {
        let (p, d, dg) = seeded("pend");
        wal::append(&p.wal_path("g"), &wal::WalRecord::Drop { version: dg.version() })
            .unwrap();
        let report = fsck_dir(&d).unwrap();
        assert_eq!(report.fatal_count(), 0, "{:?}", report.findings);
        assert!(report.findings.iter().any(|f| f.message.contains("DROP marker")));
        // read-only: unlike recovery, fsck must NOT complete the deletion
        assert!(p.wal_path("g").exists());
        assert!(!p.snapshots_of("g").is_empty());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn stale_incarnation_frames_are_info_only() {
        let (p, d, _dg) = seeded("stale");
        // a re-LOAD's snapshot landed but the old WAL survived the crash
        let g1 = from_edges(2, 2, &[(0, 1)]);
        snapshot::write_snapshot(&p.snap_path("g", 9 << 32), 9 << 32, &g1, None).unwrap();
        let report = fsck_dir(&d).unwrap();
        assert_eq!(report.fatal_count(), 0, "{:?}", report.findings);
        assert!(report.findings.iter().any(|f| {
            f.severity == Severity::Info && f.message.contains("stale frame")
        }));
        let _ = std::fs::remove_dir_all(&d);
    }

    fn seeded_sharded(tag: &str, shards: usize) -> (Persistence, PathBuf) {
        let d = dir(tag);
        let p = Persistence::open(&d).unwrap();
        p.set_snapshot_shards(shards);
        let g = crate::graph::gen::Family::Uniform.generate(200, 3);
        let base = 2u64 << 32;
        p.record_load("g", &g, base).unwrap();
        let mut dg = DynamicGraph::new(g).with_version_base(base);
        let rep = dg.apply(&DeltaBatch::new().insert(0, 1));
        p.append_update("g", dg.version(), &rep).unwrap();
        (p, d)
    }

    #[test]
    fn clean_sharded_dir_reports_the_assembled_anchor() {
        let (_p, d) = seeded_sharded("shardclean", 4);
        let report = fsck_dir(&d).unwrap();
        assert_eq!(report.fatal_count(), 0, "{:?}", report.findings);
        assert_eq!(report.repairable_count(), 0, "{:?}", report.findings);
        assert!(
            report.findings.iter().any(|f| f.message.contains("assembled 4-shard")),
            "{:?}",
            report.findings
        );
        assert!(report.findings.iter().any(|f| f.message.contains("recovers at")));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_shard_member_is_fatal() {
        let (p, d) = seeded_sharded("shardmiss", 4);
        std::fs::remove_file(p.shard_snap_path("g", 2 << 32, 1, 4)).unwrap();
        let report = fsck_dir(&d).unwrap();
        assert!(report.fatal_count() >= 1, "{:?}", report.findings);
        assert!(
            report.findings.iter().any(|f| f.message.contains("incomplete shard set")),
            "{:?}",
            report.findings
        );
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_shard_member_is_fatal() {
        let (p, d) = seeded_sharded("shardrot", 2);
        let member = p.shard_snap_path("g", 2 << 32, 0, 2);
        let mut bytes = std::fs::read(&member).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&member, &bytes).unwrap();
        let report = fsck_dir(&d).unwrap();
        assert!(report.fatal_count() >= 1, "{:?}", report.findings);
        assert!(
            report.findings.iter().any(|f| f.message.contains("fails its checksum")),
            "{:?}",
            report.findings
        );
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(fsck_dir(Path::new("/no/such/bimatch-dir")).is_err());
    }

    #[test]
    fn observability_artifacts_are_info_never_fatal() {
        let (_p, d, _dg) = seeded("obsfiles");
        // what a server leaves behind: the event log and a flight
        // recorder dir with one postmortem dump plus a stray temp file
        std::fs::write(d.join("events.jsonl"), "{\"ts_ms\":1,\"event\":\"x\"}\n").unwrap();
        std::fs::create_dir_all(d.join("flightrec")).unwrap();
        std::fs::write(d.join("flightrec/latest.jsonl"), "{}\n").unwrap();
        std::fs::write(d.join("flightrec/latest.jsonl.tmp"), "").unwrap();
        let report = fsck_dir(&d).unwrap();
        assert_eq!(report.fatal_count(), 0, "{:?}", report.findings);
        assert_eq!(report.repairable_count(), 0, "{:?}", report.findings);
        assert!(
            report.findings.iter().any(|f| {
                f.severity == Severity::Info && f.message.contains("events.jsonl present")
            }),
            "{:?}",
            report.findings
        );
        assert!(
            report.findings.iter().any(|f| {
                f.severity == Severity::Info
                    && f.message.contains("flightrec/ present (1 dump file(s))")
            }),
            "{:?}",
            report.findings
        );
        // the graph findings are untouched by the extra files
        assert_eq!(report.graphs, vec!["g".to_string()]);
        let _ = std::fs::remove_dir_all(&d);
    }
}
