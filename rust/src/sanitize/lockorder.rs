//! Lock-order watchdog: a debug-build global acquisition graph that
//! panics on the first cycle.
//!
//! PRs 4–6 stacked several lock families whose ordering discipline was
//! previously enforced only by comments. The watchdog makes the
//! documented partial order machine-checked: every instrumented lock
//! site records, for each lock class already held by the thread, a
//! `held → acquiring` edge in a process-global graph; if inserting an
//! edge would close a cycle, the acquisition panics immediately with
//! both directions' source locations — turning a potential deadlock
//! (which needs an unlucky interleaving to bite) into a deterministic
//! test failure on *any* thread that merely attempts the inversion.
//!
//! ## The documented order
//!
//! ```text
//! Entry  →  Name  →  Recency  →  StoreMap
//!   \______↘  ↓  ↘_____↘
//!          NameTable,  Hub,  SpecStats   (leaves: nothing under them)
//! ```
//!
//! * [`LockClass::Entry`] — a graph's `Mutex<StoreEntry>`
//!   (`coordinator/store.rs`); outermost: UPDATE/DROP/SAVE/eviction hold
//!   it across WAL appends, snapshots, replication publishes, and map
//!   surgery.
//! * [`LockClass::Name`] — a persistence per-name lock
//!   (`persist/mod.rs`), serializing disk state transitions for one
//!   graph name; acquired under `Entry` (DROP, eviction) and over the
//!   store/recency maps (LOAD installs, reloads).
//! * [`LockClass::Recency`] — the store's LRU recency list; acquired
//!   under `Entry`/`Name` and over `StoreMap` (`lru_victim` scans the
//!   recency order, then peeks the map).
//! * [`LockClass::StoreMap`] — the name → entry map itself; innermost.
//! * [`LockClass::NameTable`] — the table handing out per-name lock
//!   handles; a leaf held only for the handle lookup.
//! * [`LockClass::Hub`] — the replication hub's state; a leaf
//!   (publishes happen under `Entry`/`Name`, nothing locks under it).
//! * [`LockClass::SpecStats`] — the metrics per-spec aggregation map
//!   (`coordinator/metrics.rs`); a leaf held only to bump counters.
//!
//! Same-class edges are not recorded: no code path holds two locks of
//! one class at once (entries are processed one at a time everywhere),
//! and intra-class ordering would need instance identities, not classes.
//!
//! In release builds every hook compiles to nothing: [`acquire`] returns
//! a zero-sized token and [`lock`] is exactly `Mutex::lock().unwrap()`.

use std::sync::{Mutex, MutexGuard};

/// The instrumented lock classes. `TestA`/`TestB` exist solely for the
/// watchdog's own negative tests, so a manufactured inversion cannot
/// poison the real classes' edge set for the rest of the process.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LockClass {
    /// per-graph `Mutex<StoreEntry>` in the coordinator store
    Entry,
    /// per-name persistence lock (disk-state transitions)
    Name,
    /// the table that hands out per-name lock handles
    NameTable,
    /// the store's LRU recency list
    Recency,
    /// the store's name → entry map
    StoreMap,
    /// the replication hub state
    Hub,
    /// the metrics per-spec aggregation map (leaf: held only to bump
    /// counters, nothing acquired under it)
    SpecStats,
    /// the event-log sink state (leaf: held only to rate-limit and
    /// write one line, nothing acquired under it)
    Obs,
    /// watchdog negative tests only
    TestA,
    /// watchdog negative tests only
    TestB,
}

#[cfg(debug_assertions)]
mod imp {
    use super::LockClass;
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::panic::Location;
    use std::sync::Mutex;

    thread_local! {
        static HELD: RefCell<Vec<LockClass>> = const { RefCell::new(Vec::new()) };
    }

    /// held → acquiring edges, with the site that first recorded each.
    static EDGES: Mutex<Option<HashMap<(LockClass, LockClass), &'static Location<'static>>>> =
        Mutex::new(None);

    fn reachable(
        edges: &HashMap<(LockClass, LockClass), &'static Location<'static>>,
        from: LockClass,
        to: LockClass,
        seen: &mut Vec<LockClass>,
    ) -> bool {
        for &(a, b) in edges.keys() {
            if a != from || seen.contains(&b) {
                continue;
            }
            if b == to {
                return true;
            }
            seen.push(b);
            if reachable(edges, b, to, seen) {
                return true;
            }
        }
        false
    }

    /// Is there a `from ⇝ to` path? Returns the location of the first
    /// edge out of `from` on such a path, for the diagnostic. The graph
    /// has ≤ 8 nodes, so the recursive DFS is trivially bounded.
    fn path_exists(
        edges: &HashMap<(LockClass, LockClass), &'static Location<'static>>,
        from: LockClass,
        to: LockClass,
    ) -> Option<&'static Location<'static>> {
        for (&(a, b), &loc) in edges.iter() {
            if a != from {
                continue;
            }
            if b == to || reachable(edges, b, to, &mut vec![from, b]) {
                return Some(loc);
            }
        }
        None
    }

    /// Must-not-drop token for one acquisition (debug builds).
    #[derive(Debug)]
    pub struct LockToken {
        class: LockClass,
    }

    pub fn acquire(class: LockClass, loc: &'static Location<'static>) -> LockToken {
        HELD.with(|h| {
            let held = h.borrow();
            if !held.is_empty() {
                let mut edges = EDGES.lock().unwrap();
                let edges = edges.get_or_insert_with(HashMap::new);
                for &held_class in held.iter() {
                    if held_class == class || edges.contains_key(&(held_class, class)) {
                        continue;
                    }
                    if let Some(rev) = path_exists(edges, class, held_class) {
                        panic!(
                            "lock-order violation: acquiring {class:?} at {loc} while \
                             holding {held_class:?}, but the reverse order \
                             {class:?} → … → {held_class:?} was already observed \
                             (first hop recorded at {rev})"
                        );
                    }
                    edges.insert((held_class, class), loc);
                }
            }
        });
        HELD.with(|h| h.borrow_mut().push(class));
        LockToken { class }
    }

    impl Drop for LockToken {
        fn drop(&mut self) {
            // locks are not always released LIFO (guards get dropped
            // early by name), so pop the last matching entry by class
            HELD.with(|h| {
                let mut held = h.borrow_mut();
                if let Some(pos) = held.iter().rposition(|&c| c == self.class) {
                    held.remove(pos);
                }
            });
        }
    }
}

#[cfg(not(debug_assertions))]
mod imp {
    use super::LockClass;

    #[derive(Debug)]
    pub struct LockToken;

    #[inline(always)]
    pub fn acquire(_class: LockClass, _loc: &'static std::panic::Location<'static>) -> LockToken {
        LockToken
    }
}

pub use imp::LockToken;

/// Record an acquisition of `class` by this thread (debug builds; a
/// no-op token in release). Hold the returned token for exactly as long
/// as the lock guard lives — prefer [`lock`], which ties the two
/// lifetimes together so an early `drop(guard)` can never leave a stale
/// token manufacturing false edges.
#[track_caller]
pub fn acquire(class: LockClass) -> LockToken {
    imp::acquire(class, std::panic::Location::caller())
}

/// A `MutexGuard` paired with its watchdog token: drops both together.
#[derive(Debug)]
pub struct Tracked<'a, T> {
    guard: MutexGuard<'a, T>,
    _token: LockToken,
}

impl<T> std::ops::Deref for Tracked<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> std::ops::DerefMut for Tracked<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

/// `m.lock().unwrap()` with the acquisition recorded under `class`.
/// Panics on a poisoned mutex exactly like the bare `.unwrap()` did, and
/// compiles to exactly that in release builds.
#[track_caller]
pub fn lock<'a, T>(class: LockClass, m: &'a Mutex<T>) -> Tracked<'a, T> {
    let token = imp::acquire(class, std::panic::Location::caller());
    Tracked { guard: m.lock().unwrap(), _token: token }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consistent_nesting_is_quiet() {
        let a = Mutex::new(1);
        let b = Mutex::new(2);
        for _ in 0..3 {
            let ga = lock(LockClass::Entry, &a);
            let gb = lock(LockClass::StoreMap, &b);
            assert_eq!(*ga + *gb, 3);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    fn early_guard_drop_releases_the_token() {
        // drop(entry) mid-scope, then take a lock that would invert the
        // order *if* the token were stale — it must stay quiet
        let a = Mutex::new(());
        let b = Mutex::new(());
        {
            let ga = lock(LockClass::TestB, &a);
            drop(ga);
            // TestB no longer held: no TestB → TestA edge is recorded
            let _gb = lock(LockClass::TestA, &b);
        }
        {
            // so the reverse nesting later is not a cycle either way
            let _ga = lock(LockClass::TestA, &a);
        }
    }
}
