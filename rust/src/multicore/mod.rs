//! Multicore matching baselines (Azad et al., IPDPS 2012 — the paper's
//! P-HK, P-PFP, and P-DBFS comparators), implemented with `std::thread`
//! scoped pools and atomics.
//!
//! NOTE: the evaluation container exposes a single CPU, so wall-clock
//! *speedups* of these codes are flat here; the algorithms are still the
//! real parallel formulations (claim-based disjoint searches, CAS row
//! acquisition) and their work counters feed the harness.

pub mod common;
pub mod pdbfs;
pub mod phk;
pub mod ppfp;

pub use pdbfs::PDbfs;
pub use phk::PHk;
pub use ppfp::PPfp;
