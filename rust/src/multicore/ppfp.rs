//! P-PFP — multicore Pothen–Fan with fairness (Azad et al. [1]): threads
//! grab unmatched columns and run DFS+lookahead searches concurrently,
//! claiming rows with CAS so realized augmenting paths are vertex-disjoint.
//! Rounds alternate scan direction (fairness); a sequential PFP tail
//! certifies termination after a zero-augmentation round (claim starvation
//! cannot hide remaining augmenting paths from the tail).
//!
//! In the paper this baseline is more robust to RCP permutation than
//! P-DBFS but loses to it overall (Fig. 3/4).

use super::common::{AtomicMatching, Stamps};
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunResult};
use crate::matching::{Matching, UNMATCHED};
use crate::util::pool::{default_threads, fork_join};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Per-thread DFS scratch (col/row/ptr stacks), leased from the ctx pool
/// once per run.
type Scratch = (Vec<u32>, Vec<u32>, Vec<u32>);

fn give_scratch(ctx: &RunCtx, scratch: Vec<Mutex<Scratch>>) {
    for slot in scratch {
        let (cols, rows, ptrs) = slot.into_inner().expect("scratch slot poisoned");
        ctx.give_u32(cols);
        ctx.give_u32(rows);
        ctx.give_u32(ptrs);
    }
}

pub struct PPfp {
    pub nthreads: usize,
}

impl Default for PPfp {
    fn default() -> Self {
        Self { nthreads: default_threads() }
    }
}

impl MatchingAlgorithm for PPfp {
    fn name(&self) -> String {
        // the AlgoSpec wire format with an explicit thread count
        format!("p-pfp@{}", self.nthreads)
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let am = AtomicMatching::from(&init);
        let row_claim = Stamps::new(g.nr);
        let mut stamp = 0u32;
        let mut forward = true;
        let mut total_aug = 0u64;
        // per-thread DFS stacks leased once per *run* (not re-allocated
        // per round): each thread locks its own slot, uncontended
        let scratch: Vec<Mutex<Scratch>> = (0..self.nthreads)
            .map(|_| {
                Mutex::new((
                    ctx.lease_worklist_u32(0),
                    ctx.lease_worklist_u32(0),
                    ctx.lease_worklist_u32(0),
                ))
            })
            .collect();

        loop {
            if let Some(trip) = ctx.checkpoint() {
                ctx.stats.augmentations = total_aug;
                give_scratch(ctx, scratch);
                return ctx.finish_with(am.into_matching(), trip);
            }
            stamp += 1;
            let work = AtomicUsize::new(0);
            let aug = AtomicU64::new(0);
            let scanned_total = AtomicU64::new(0);
            let fwd = forward;
            fork_join(self.nthreads, |tid| {
                let mut slot = scratch[tid].lock().expect("scratch slot poisoned");
                let (col_stack, row_stack, ptr_stack) = &mut *slot;
                let mut scanned = 0u64;
                loop {
                    let c0 = work.fetch_add(1, Ordering::Relaxed);
                    if c0 >= g.nc {
                        break;
                    }
                    if am.cmatch_load(c0) != UNMATCHED || g.col_degree(c0) == 0 {
                        continue;
                    }
                    if dfs_la_claimed(
                        g, &am, &row_claim, stamp, c0, fwd,
                        col_stack, row_stack, ptr_stack, &mut scanned,
                    ) {
                        aug.fetch_add(1, Ordering::Relaxed);
                    }
                }
                scanned_total.fetch_add(scanned, Ordering::Relaxed);
            });
            ctx.stats.edges_scanned += scanned_total.load(Ordering::Relaxed);
            ctx.record_phase(0);
            let a = aug.load(Ordering::Relaxed);
            total_aug += a;
            if a == 0 {
                break;
            }
            forward = !forward;
        }

        give_scratch(ctx, scratch);
        // sequential tail certifies maximality (and picks up any paths the
        // claim discipline starved out).
        let tail = crate::seq::Pfp.run(g, am.into_matching(), &mut ctx.fork());
        ctx.stats.augmentations = total_aug + tail.stats.augmentations;
        ctx.stats.edges_scanned += tail.stats.edges_scanned;
        ctx.finish_with(tail.matching, tail.outcome)
    }
}

/// DFS with lookahead where rows are claimed (per-round stamps). Unlike the
/// sequential PFP the claims persist for the whole round — that is exactly
/// the Azad et al. design: disjointness buys lock-free augmentation at the
/// cost of possibly starving other searches (fixed by later rounds/tail).
#[allow(clippy::too_many_arguments)]
fn dfs_la_claimed(
    g: &BipartiteCsr,
    am: &AtomicMatching,
    row_claim: &Stamps,
    stamp: u32,
    c0: usize,
    forward: bool,
    col_stack: &mut Vec<u32>,
    row_stack: &mut Vec<u32>,
    ptr_stack: &mut Vec<u32>,
    scanned: &mut u64,
) -> bool {
    col_stack.clear();
    row_stack.clear();
    ptr_stack.clear();
    col_stack.push(c0 as u32);
    ptr_stack.push(0);
    while let Some(&c) = col_stack.last() {
        let c = c as usize;
        let base = g.cxadj[c] as usize;
        let deg = g.col_degree(c);
        let mut advanced = false;
        while (*ptr_stack.last().unwrap() as usize) < deg {
            let k = *ptr_stack.last().unwrap() as usize;
            *ptr_stack.last_mut().unwrap() += 1;
            let idx = if forward { k } else { deg - 1 - k };
            let r = g.cadj[base + idx] as usize;
            *scanned += 1;
            if !row_claim.claim(r, stamp) {
                continue;
            }
            if am.try_claim_row(r, c) {
                // free row won: flip the private path
                row_stack.push(r as u32);
                for i in (0..col_stack.len()).rev() {
                    am.set_pair(row_stack[i] as usize, col_stack[i] as usize);
                }
                return true;
            }
            let rm = am.rmatch_load(r);
            if rm == UNMATCHED {
                continue;
            }
            let c2 = rm as usize;
            row_stack.push(r as u32);
            col_stack.push(c2 as u32);
            ptr_stack.push(0);
            advanced = true;
            break;
        }
        if !advanced {
            col_stack.pop();
            row_stack.pop();
            ptr_stack.pop();
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn ppfp_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = PPfp { nthreads: 4 }.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn prop_ppfp_matches_reference() {
        forall(Config::cases(30), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            for nthreads in [1, 4] {
                let r = PPfp { nthreads }.run_detached(&g, Matching::empty(nr, nc));
                r.matching.certify(&g).map_err(|e| e.to_string())?;
                if r.matching.cardinality() != reference_max_cardinality(&g) {
                    return Err(format!("p-pfp[{nthreads}] suboptimal"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ppfp_leases_thread_scratch_from_the_ctx_pool() {
        use crate::matching::algo::RunCtx;
        use crate::util::pool::WorkspacePool;
        use std::sync::Arc;
        let g = crate::graph::gen::Family::Uniform.generate(600, 7);
        let algo = PPfp { nthreads: 8 };
        let pool = Arc::new(WorkspacePool::new());
        let mut ctx = RunCtx::new(pool.clone());
        let r = algo.run(&g, InitHeuristic::Cheap.run(&g), &mut ctx);
        r.matching.certify(&g).unwrap();
        // three stacks per thread come back; the sequential tail alone
        // returns far fewer than 3 × 8 buffers
        assert!(pool.returns() >= 24, "scratch not returned: {} returns", pool.returns());
        let reuses_before = pool.reuses();
        let mut ctx = RunCtx::new(pool.clone());
        let r = algo.run(&g, InitHeuristic::Cheap.run(&g), &mut ctx);
        r.matching.certify(&g).unwrap();
        assert!(
            pool.reuses() > reuses_before,
            "second run must lease the first run's scratch from the shelf"
        );
    }

    #[test]
    fn ppfp_permuted_instance() {
        let g = crate::graph::gen::Family::Banded.generate(700, 13);
        let p = crate::graph::random_permute(&g, 5);
        let r = PPfp { nthreads: 4 }.run_detached(&p, InitHeuristic::Cheap.run(&p));
        r.matching.certify(&p).unwrap();
        assert_eq!(r.matching.cardinality(), reference_max_cardinality(&p));
    }
}
