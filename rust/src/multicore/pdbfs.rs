//! P-DBFS — parallel disjoint BFS (Azad et al. [1]): every thread grabs an
//! unmatched column and runs a *private* BFS whose vertices it claims
//! atomically, so concurrent searches explore disjoint regions and can
//! augment without locks. Columns whose search was starved by claims are
//! retried in the next round; termination is certified by a sequential
//! Hopcroft–Karp tail on the (few) remaining columns.
//!
//! In the paper's experiments this is the strongest multicore baseline on
//! original orderings, degrading under RCP permutation (Fig. 3).

use super::common::{AtomicMatching, Stamps};
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunResult};
use crate::matching::{Matching, UNMATCHED};
use crate::util::pool::{default_threads, fork_join};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Per-thread BFS scratch: frontier/next worklists plus the private
/// predecessor array, leased from the ctx pool once per run.
type Scratch = (Vec<u32>, Vec<u32>, Vec<i32>);

fn give_scratch(ctx: &RunCtx, scratch: Vec<Mutex<Scratch>>) {
    for slot in scratch {
        let (frontier, next, pred) = slot.into_inner().expect("scratch slot poisoned");
        ctx.give_u32(frontier);
        ctx.give_u32(next);
        ctx.give_i32(pred);
    }
}

pub struct PDbfs {
    pub nthreads: usize,
}

impl Default for PDbfs {
    fn default() -> Self {
        Self { nthreads: default_threads() }
    }
}

impl MatchingAlgorithm for PDbfs {
    fn name(&self) -> String {
        // the AlgoSpec wire format with an explicit thread count
        format!("p-dbfs@{}", self.nthreads)
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let am = AtomicMatching::from(&init);
        let col_claim = Stamps::new(g.nc);
        let row_claim = Stamps::new(g.nr);
        let mut stamp = 0u32;
        let total_aug = AtomicU64::new(0);
        // per-thread scratch leased once per *run* (not re-allocated per
        // round): each thread locks its own slot, so the mutex is
        // uncontended. `pred` is never reset between rounds — every read
        // happens behind a same-round row claim, whose success wrote the
        // entry first.
        let scratch: Vec<Mutex<Scratch>> = (0..self.nthreads)
            .map(|_| {
                Mutex::new((
                    ctx.lease_worklist_u32(0),
                    ctx.lease_worklist_u32(0),
                    ctx.lease_i32(g.nr, -1),
                ))
            })
            .collect();

        loop {
            if let Some(trip) = ctx.checkpoint() {
                ctx.stats.augmentations = total_aug.load(Ordering::Relaxed);
                give_scratch(ctx, scratch);
                return ctx.finish_with(am.into_matching(), trip);
            }
            stamp += 1;
            let work = AtomicUsize::new(0);
            let round_aug = AtomicU64::new(0);
            let edges_scanned = AtomicU64::new(0);
            fork_join(self.nthreads, |tid| {
                // thread-private BFS buffers (own slot, uncontended lock)
                let mut slot = scratch[tid].lock().expect("scratch slot poisoned");
                let (frontier, next, pred) = &mut *slot;
                let mut scanned = 0u64;
                loop {
                    let c0 = work.fetch_add(1, Ordering::Relaxed);
                    if c0 >= g.nc {
                        break;
                    }
                    if am.cmatch_load(c0) != UNMATCHED || g.col_degree(c0) == 0 {
                        continue;
                    }
                    if !col_claim.claim(c0, stamp) {
                        continue;
                    }
                    if let Some(endpoint) =
                        bfs_search(g, &am, &col_claim, &row_claim, stamp, c0, frontier, next, pred, &mut scanned)
                    {
                        // augment along private predecessors; all rows on
                        // the path were claimed by this search, the free
                        // endpoint row was CAS-acquired — flip is exclusive.
                        let mut r = endpoint;
                        loop {
                            let c = pred[r] as usize;
                            let prev_r = am.cmatch_load(c);
                            am.set_pair(r, c);
                            if prev_r == UNMATCHED {
                                break;
                            }
                            r = prev_r as usize;
                        }
                        round_aug.fetch_add(1, Ordering::Relaxed);
                    }
                }
                edges_scanned.fetch_add(scanned, Ordering::Relaxed);
            });
            ctx.stats.edges_scanned += edges_scanned.load(Ordering::Relaxed);
            let aug = round_aug.load(Ordering::Relaxed);
            total_aug.fetch_add(aug, Ordering::Relaxed);
            ctx.record_phase(1);
            if aug == 0 {
                break; // starvation or true maximality — certified below
            }
        }

        give_scratch(ctx, scratch);
        // sequential certification tail: claims may have starved real
        // augmenting paths; HK from the current matching finishes the job
        // and proves maximality (cheap — few unmatched columns remain).
        let m = am.into_matching();
        let tail = crate::seq::Hk.run(g, m, &mut ctx.fork());
        ctx.stats.augmentations = total_aug.load(Ordering::Relaxed) + tail.stats.augmentations;
        ctx.stats.edges_scanned += tail.stats.edges_scanned;
        ctx.finish_with(tail.matching, tail.outcome)
    }
}

/// One claimed BFS from `c0`: expands only through vertices this search
/// wins; returns a free row whose claim (CAS on rmatch) succeeded.
#[allow(clippy::too_many_arguments)]
fn bfs_search(
    g: &BipartiteCsr,
    am: &AtomicMatching,
    col_claim: &Stamps,
    row_claim: &Stamps,
    stamp: u32,
    c0: usize,
    frontier: &mut Vec<u32>,
    next: &mut Vec<u32>,
    pred: &mut [i32],
    scanned: &mut u64,
) -> Option<usize> {
    frontier.clear();
    next.clear();
    frontier.push(c0 as u32);
    while !frontier.is_empty() {
        for &c in frontier.iter() {
            for &r in g.col_neighbors(c as usize) {
                let r = r as usize;
                *scanned += 1;
                if !row_claim.claim(r, stamp) {
                    continue;
                }
                pred[r] = c as i32;
                // free row? claim it by CAS to a provisional value
                if am.try_claim_row(r, c as usize) {
                    return Some(r);
                }
                let rm = am.rmatch_load(r);
                if rm == UNMATCHED {
                    continue; // lost a race; someone else took it just now
                }
                let c2 = rm as usize;
                if col_claim.claim(c2, stamp) {
                    next.push(c2 as u32);
                }
            }
        }
        std::mem::swap(frontier, next);
        next.clear();
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn pdbfs_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = PDbfs { nthreads: 4 }.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn prop_pdbfs_matches_reference() {
        forall(Config::cases(30), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            for nthreads in [1, 4] {
                let r = PDbfs { nthreads }.run_detached(&g, Matching::empty(nr, nc));
                r.matching.certify(&g).map_err(|e| e.to_string())?;
                if r.matching.cardinality() != reference_max_cardinality(&g) {
                    return Err(format!("p-dbfs[{nthreads}] suboptimal"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pdbfs_leases_thread_scratch_from_the_ctx_pool() {
        use crate::matching::algo::RunCtx;
        use crate::util::pool::WorkspacePool;
        use std::sync::Arc;
        let g = crate::graph::gen::Family::Uniform.generate(600, 3);
        let algo = PDbfs { nthreads: 8 };
        let pool = Arc::new(WorkspacePool::new());
        let before = pool.returns();
        let mut ctx = RunCtx::new(pool.clone());
        let r = algo.run(&g, InitHeuristic::Cheap.run(&g), &mut ctx);
        r.matching.certify(&g).unwrap();
        // frontier + next + pred per thread all come back to the shelf;
        // the sequential tail alone returns far fewer than 3 × 8 buffers
        assert!(
            pool.returns() - before >= 24,
            "per-thread scratch not returned: {} returns",
            pool.returns() - before
        );
        let reuses_before = pool.reuses();
        let mut ctx = RunCtx::new(pool.clone());
        let r = algo.run(&g, InitHeuristic::Cheap.run(&g), &mut ctx);
        r.matching.certify(&g).unwrap();
        assert!(
            pool.reuses() > reuses_before,
            "second run must lease the first run's scratch from the shelf"
        );
    }

    #[test]
    fn pdbfs_on_generated_families() {
        for fam in [crate::graph::gen::Family::Road, crate::graph::gen::Family::Social] {
            let g = fam.generate(800, 11);
            let init = InitHeuristic::Cheap.run(&g);
            let r = PDbfs { nthreads: 4 }.run_detached(&g, init);
            r.matching.certify(&g).unwrap();
            assert_eq!(r.matching.cardinality(), reference_max_cardinality(&g));
        }
    }
}
