//! P-HK — multicore Hopcroft–Karp (Azad et al. [1]): the level-building
//! BFS is parallelized level-synchronously with atomic distance updates,
//! and the shortest-path DFS phase runs one search per thread with atomic
//! row claiming (a maximal-*ish* disjoint set; missed paths are retried in
//! later phases, so the HK termination proof still applies — the outer
//! loop only exits when a BFS finds no augmenting path at all).

use super::common::{AtomicMatching, Stamps};
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunResult};
use crate::matching::{Matching, UNMATCHED};
use crate::util::pool::{default_threads, fork_join, parallel_chunks};
use std::sync::atomic::{AtomicBool, AtomicI32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

pub struct PHk {
    pub nthreads: usize,
}

impl Default for PHk {
    fn default() -> Self {
        Self { nthreads: default_threads() }
    }
}

const UNREACHED: i32 = i32::MAX;

/// Per-thread scratch leased from the ctx pool once per run: slot 0
/// doubles as the BFS phase's `local_next` buffer and the DFS phase's
/// column stack; slots 1/2 are the DFS row/pointer stacks.
type Scratch = (Vec<u32>, Vec<u32>, Vec<u32>);

fn give_scratch(ctx: &RunCtx, scratch: Vec<Mutex<Scratch>>) {
    for slot in scratch {
        let (a, b, c) = slot.into_inner().expect("scratch slot poisoned");
        ctx.give_u32(a);
        ctx.give_u32(b);
        ctx.give_u32(c);
    }
}

impl MatchingAlgorithm for PHk {
    fn name(&self) -> String {
        // the AlgoSpec wire format with an explicit thread count
        format!("p-hk@{}", self.nthreads)
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let am = AtomicMatching::from(&init);
        let dist: Vec<AtomicI32> = (0..g.nc).map(|_| AtomicI32::new(UNREACHED)).collect();
        let row_claim = Stamps::new(g.nr);
        let mut stamp = 0u32;
        let mut total_aug = 0u64;
        // per-thread scratch leased once per *run* (not re-allocated per
        // BFS level / DFS round): each thread locks its own slot,
        // uncontended
        let scratch: Vec<Mutex<Scratch>> = (0..self.nthreads)
            .map(|_| {
                Mutex::new((
                    ctx.lease_worklist_u32(0),
                    ctx.lease_worklist_u32(0),
                    ctx.lease_worklist_u32(0),
                ))
            })
            .collect();

        loop {
            if let Some(trip) = ctx.checkpoint() {
                ctx.stats.augmentations = total_aug;
                give_scratch(ctx, scratch);
                return ctx.finish_with(am.into_matching(), trip);
            }
            // ---- parallel level-synchronous BFS ----
            parallel_chunks(self.nthreads, g.nc, |range| {
                for c in range {
                    dist[c].store(UNREACHED, Ordering::Relaxed);
                }
            });
            let frontier: Mutex<Vec<u32>> = Mutex::new(
                (0..g.nc)
                    .filter(|&c| am.cmatch_load(c) == UNMATCHED && g.col_degree(c) > 0)
                    .map(|c| c as u32)
                    .collect(),
            );
            {
                let f = frontier.lock().unwrap();
                for &c in f.iter() {
                    dist[c as usize].store(0, Ordering::Relaxed);
                }
            }
            let mut level = 0i32;
            let mut found = false;
            let mut launches = 0u32;
            let edges_scanned = AtomicU64::new(0);
            loop {
                let cur = std::mem::take(&mut *frontier.lock().unwrap());
                if cur.is_empty() || found {
                    break;
                }
                launches += 1;
                let found_flag = AtomicBool::new(false);
                let work = AtomicUsize::new(0);
                fork_join(self.nthreads, |tid| {
                    let mut slot = scratch[tid].lock().expect("scratch slot poisoned");
                    let local_next = &mut slot.0;
                    local_next.clear();
                    let mut scanned = 0u64;
                    loop {
                        let i = work.fetch_add(1, Ordering::Relaxed);
                        if i >= cur.len() {
                            break;
                        }
                        let c = cur[i] as usize;
                        for &r in g.col_neighbors(c) {
                            scanned += 1;
                            let rm = am.rmatch_load(r as usize);
                            if rm == UNMATCHED {
                                found_flag.store(true, Ordering::Relaxed);
                            } else {
                                let c2 = rm as usize;
                                if dist[c2]
                                    .compare_exchange(
                                        UNREACHED,
                                        level + 1,
                                        Ordering::AcqRel,
                                        Ordering::Relaxed,
                                    )
                                    .is_ok()
                                {
                                    local_next.push(c2 as u32);
                                }
                            }
                        }
                    }
                    edges_scanned.fetch_add(scanned, Ordering::Relaxed);
                    if !local_next.is_empty() {
                        frontier.lock().unwrap().extend_from_slice(local_next);
                    }
                });
                found = found_flag.load(Ordering::Relaxed);
                level += 1;
            }
            ctx.stats.edges_scanned += edges_scanned.load(Ordering::Relaxed);
            if !found {
                break; // certified maximum: no augmenting path exists
            }
            ctx.record_phase(launches);

            // ---- parallel disjoint shortest-path DFS ----
            stamp += 1;
            let work = AtomicUsize::new(0);
            let aug = AtomicU64::new(0);
            fork_join(self.nthreads, |tid| {
                let mut slot = scratch[tid].lock().expect("scratch slot poisoned");
                let (col_stack, row_stack, ptr_stack) = &mut *slot;
                loop {
                    let c0 = work.fetch_add(1, Ordering::Relaxed);
                    if c0 >= g.nc {
                        break;
                    }
                    if am.cmatch_load(c0) != UNMATCHED
                        || g.col_degree(c0) == 0
                        || dist[c0].load(Ordering::Relaxed) != 0
                    {
                        continue;
                    }
                    if dfs_claimed(
                        g, &am, &dist, &row_claim, stamp, c0,
                        col_stack, row_stack, ptr_stack,
                    ) {
                        aug.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
            total_aug += aug.load(Ordering::Relaxed);
            // if the claimed DFS found nothing despite BFS success (pure
            // starvation), fall back to one sequential HK phase to ensure
            // progress and hence termination.
            if aug.load(Ordering::Relaxed) == 0 {
                give_scratch(ctx, scratch);
                let m = am.into_matching();
                let tail = crate::seq::Hk.run(g, m, &mut ctx.fork());
                ctx.stats.augmentations = total_aug + tail.stats.augmentations;
                ctx.stats.edges_scanned += tail.stats.edges_scanned;
                return ctx.finish_with(tail.matching, tail.outcome);
            }
        }
        give_scratch(ctx, scratch);
        ctx.stats.augmentations = total_aug;
        ctx.finish(am.into_matching())
    }
}

/// Level-restricted iterative DFS with atomic row claiming.
#[allow(clippy::too_many_arguments)]
fn dfs_claimed(
    g: &BipartiteCsr,
    am: &AtomicMatching,
    dist: &[AtomicI32],
    row_claim: &Stamps,
    stamp: u32,
    c0: usize,
    col_stack: &mut Vec<u32>,
    row_stack: &mut Vec<u32>,
    ptr_stack: &mut Vec<u32>,
) -> bool {
    col_stack.clear();
    row_stack.clear();
    ptr_stack.clear();
    col_stack.push(c0 as u32);
    ptr_stack.push(g.cxadj[c0]);
    while let Some(&c) = col_stack.last() {
        let c = c as usize;
        let dc = dist[c].load(Ordering::Relaxed);
        let mut advanced = false;
        while *ptr_stack.last().unwrap() < g.cxadj[c + 1] {
            let r = g.cadj[*ptr_stack.last().unwrap() as usize] as usize;
            *ptr_stack.last_mut().unwrap() += 1;
            // read the match first: claiming a row whose edge fails the
            // level check would starve the row's one legitimate user (the
            // level-graph bug fixed in seq::hk::dfs_augment); here a
            // wrongly-claimed row merely costs fallback work, but the same
            // discipline keeps the parallel phase effective.
            let rm = am.rmatch_load(r);
            if rm == UNMATCHED {
                // free row: claim its visited-stamp, then CAS it
                if row_claim.claim(r, stamp) && am.try_claim_row(r, c) {
                    row_stack.push(r as u32);
                    // flip the path; all vertices exclusively claimed
                    for i in (0..col_stack.len()).rev() {
                        let (ci, ri) = (col_stack[i] as usize, row_stack[i] as usize);
                        am.set_pair(ri, ci);
                    }
                    return true;
                }
                continue;
            }
            let c2 = rm as usize;
            if dist[c2].load(Ordering::Relaxed) == dc + 1 && row_claim.claim(r, stamp) {
                row_stack.push(r as u32);
                col_stack.push(c2 as u32);
                ptr_stack.push(g.cxadj[c2]);
                advanced = true;
                break;
            }
        }
        if !advanced {
            col_stack.pop();
            row_stack.pop();
            ptr_stack.pop();
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn phk_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = PHk { nthreads: 4 }.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn prop_phk_matches_reference() {
        forall(Config::cases(30), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            for nthreads in [1, 4] {
                let r = PHk { nthreads }.run_detached(&g, Matching::empty(nr, nc));
                r.matching.certify(&g).map_err(|e| e.to_string())?;
                if r.matching.cardinality() != reference_max_cardinality(&g) {
                    return Err(format!("p-hk[{nthreads}] suboptimal"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn phk_leases_thread_scratch_from_the_ctx_pool() {
        use crate::matching::algo::RunCtx;
        use crate::util::pool::WorkspacePool;
        use std::sync::Arc;
        let g = crate::graph::gen::Family::Uniform.generate(600, 5);
        let algo = PHk { nthreads: 8 };
        let pool = Arc::new(WorkspacePool::new());
        let mut ctx = RunCtx::new(pool.clone());
        let r = algo.run(&g, InitHeuristic::Cheap.run(&g), &mut ctx);
        r.matching.certify(&g).unwrap();
        // three scratch buffers per thread come back; any sequential
        // fallback tail alone returns far fewer than 3 × 8 buffers
        assert!(pool.returns() >= 24, "scratch not returned: {} returns", pool.returns());
        let reuses_before = pool.reuses();
        let mut ctx = RunCtx::new(pool.clone());
        let r = algo.run(&g, InitHeuristic::Cheap.run(&g), &mut ctx);
        r.matching.certify(&g).unwrap();
        assert!(
            pool.reuses() > reuses_before,
            "second run must lease the first run's scratch from the shelf"
        );
    }

    #[test]
    fn phk_on_mesh_with_init() {
        let g = crate::graph::gen::delaunay_like(900, 5);
        let r = PHk { nthreads: 4 }.run_detached(&g, InitHeuristic::Cheap.run(&g));
        r.matching.certify(&g).unwrap();
        assert_eq!(r.matching.cardinality(), reference_max_cardinality(&g));
    }
}
