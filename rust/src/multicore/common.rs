//! Shared atomic state for the multicore matchers (Azad et al. [1] use
//! OpenMP + atomics; here: `std::sync::atomic` + the scoped pool).

use crate::matching::{Matching, UNMATCHED};
use std::sync::atomic::{AtomicI32, AtomicU32, Ordering};

/// Matching state accessed concurrently. Rows are *claimed* by CAS on
/// `rmatch` (free → candidate) exactly as the multicore algorithms of the
/// paper do, so successful augmentations are vertex-disjoint by
/// construction.
pub struct AtomicMatching {
    pub rmatch: Vec<AtomicI32>,
    pub cmatch: Vec<AtomicI32>,
}

impl AtomicMatching {
    pub fn from(m: &Matching) -> Self {
        Self {
            rmatch: m.rmatch.iter().map(|&v| AtomicI32::new(v)).collect(),
            cmatch: m.cmatch.iter().map(|&v| AtomicI32::new(v)).collect(),
        }
    }

    pub fn into_matching(self) -> Matching {
        Matching {
            rmatch: self.rmatch.into_iter().map(|a| a.into_inner()).collect(),
            cmatch: self.cmatch.into_iter().map(|a| a.into_inner()).collect(),
        }
    }

    #[inline]
    pub fn rmatch_load(&self, r: usize) -> i32 {
        self.rmatch[r].load(Ordering::Acquire)
    }

    #[inline]
    pub fn cmatch_load(&self, c: usize) -> i32 {
        self.cmatch[c].load(Ordering::Acquire)
    }

    /// Try to claim free row `r` for column `c`; true on success.
    #[inline]
    pub fn try_claim_row(&self, r: usize, c: usize) -> bool {
        self.rmatch[r]
            .compare_exchange(UNMATCHED, c as i32, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Unconditional writes used while flipping an augmenting path whose
    /// vertices the caller exclusively owns.
    #[inline]
    pub fn set_pair(&self, r: usize, c: usize) {
        self.rmatch[r].store(c as i32, Ordering::Release);
        self.cmatch[c].store(r as i32, Ordering::Release);
    }
}

/// Per-vertex claim stamps: CAS from a stale stamp to the current one
/// claims the vertex for exactly one search in this phase.
pub struct Stamps {
    v: Vec<AtomicU32>,
}

impl Stamps {
    pub fn new(n: usize) -> Self {
        Self { v: (0..n).map(|_| AtomicU32::new(0)).collect() }
    }

    /// Claim vertex `i` under `stamp`; true if this caller won.
    #[inline]
    pub fn claim(&self, i: usize, stamp: u32) -> bool {
        let cur = self.v[i].load(Ordering::Relaxed);
        if cur >= stamp {
            return false;
        }
        self.v[i]
            .compare_exchange(cur, stamp, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    }

    #[inline]
    pub fn is_claimed(&self, i: usize, stamp: u32) -> bool {
        self.v[i].load(Ordering::Relaxed) >= stamp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::parallel_for;

    #[test]
    fn atomic_matching_roundtrip() {
        let mut m = Matching::empty(3, 3);
        m.join(1, 2);
        let am = AtomicMatching::from(&m);
        assert_eq!(am.rmatch_load(1), 2);
        assert_eq!(am.cmatch_load(2), 1);
        let back = am.into_matching();
        assert_eq!(back, m);
    }

    #[test]
    fn claim_row_exactly_once() {
        let m = Matching::empty(1, 8);
        let am = AtomicMatching::from(&m);
        let winners = std::sync::atomic::AtomicUsize::new(0);
        parallel_for(8, 8, |c| {
            if am.try_claim_row(0, c) {
                winners.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(winners.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn stamps_claim_once_per_stamp() {
        let s = Stamps::new(4);
        assert!(s.claim(2, 1));
        assert!(!s.claim(2, 1));
        assert!(s.is_claimed(2, 1));
        // new stamp reopens the vertex
        assert!(s.claim(2, 2));
        assert!(!s.is_claimed(3, 1));
    }
}
