//! Sequential maximum-cardinality matching algorithms: the paper's two
//! baselines (HK [14] and PFP [8]), HKDW [9] (which APFB mirrors on the
//! GPU), plus two extra augmenting-path baselines and a push–relabel
//! matcher from the second algorithm class the paper surveys.

pub mod bfs;
pub mod dfs;
pub mod hk;
pub mod hkdw;
pub mod pfp;
pub mod push_relabel;

pub use bfs::BfsSimple;
pub use dfs::DfsLookahead;
pub use hk::Hk;
pub use hkdw::Hkdw;
pub use pfp::Pfp;
pub use push_relabel::PushRelabel;
