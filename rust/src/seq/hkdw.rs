//! HKDW — Hopcroft–Karp with the Duff–Wiberg improvement ([9] in the
//! paper): after each HK phase (maximal disjoint *shortest* augmenting
//! paths), run an extra round of unrestricted DFS searches from the rows
//! that are still unmatched, augmenting along arbitrary-length disjoint
//! paths. Same O(√n·τ) worst case, better practical behaviour; the paper's
//! APFB is its GPU analogue.

use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunOutcome, RunResult, RunStats};
use crate::matching::{Matching, UNMATCHED};

pub struct Hkdw;

const UNREACHED: i32 = i32::MAX;

impl MatchingAlgorithm for Hkdw {
    fn name(&self) -> String {
        "hkdw".into()
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let mut m = init;
        let mut dist = ctx.lease_i32(g.nc, UNREACHED);
        let mut frontier = ctx.lease_worklist_u32(g.nc);
        let mut next = ctx.lease_worklist_u32(g.nc);
        let mut row_visited = ctx.lease_bool(g.nr, false);
        let mut col_visited = ctx.lease_bool(g.nc, false);
        let mut ptr = ctx.lease_u32(g.nc, 0);
        let mut rptr = ctx.lease_u32(g.nr, 0);

        let mut outcome = RunOutcome::Complete;
        loop {
            if let Some(trip) = ctx.checkpoint() {
                outcome = trip;
                break;
            }
            let levels =
                super::hk::bfs_levels(g, &m, &mut dist, &mut frontier, &mut next, &mut ctx.stats);
            let Some(aug_level) = levels else { break };
            ctx.record_phase(aug_level + 1);

            // HK phase: disjoint shortest paths (same as seq::hk)
            row_visited.iter_mut().for_each(|v| *v = false);
            for c in 0..g.nc {
                ptr[c] = g.cxadj[c];
            }
            for c0 in 0..g.nc {
                if m.cmatch[c0] != UNMATCHED || dist[c0] != 0 || g.col_degree(c0) == 0 {
                    continue;
                }
                if level_dfs(g, &mut m, &dist, &mut row_visited, &mut ptr, c0, &mut ctx.stats) {
                    ctx.stats.augmentations += 1;
                }
            }

            // Duff–Wiberg extra pass: unrestricted alternating DFS from the
            // remaining unmatched *rows*, disjoint via visited marks.
            col_visited.iter_mut().for_each(|v| *v = false);
            for r in 0..g.nr {
                rptr[r] = g.rxadj[r];
            }
            for c in 0..g.nc {
                ptr[c] = g.cxadj[c];
            }
            for r0 in 0..g.nr {
                if m.rmatch[r0] != UNMATCHED || g.row_degree(r0) == 0 {
                    continue;
                }
                if row_dfs(g, &mut m, &mut col_visited, &mut rptr, r0, &mut ctx.stats) {
                    ctx.stats.augmentations += 1;
                }
            }
        }
        ctx.give_i32(dist);
        ctx.give_u32(frontier);
        ctx.give_u32(next);
        ctx.give_bool(row_visited);
        ctx.give_bool(col_visited);
        ctx.give_u32(ptr);
        ctx.give_u32(rptr);
        ctx.finish_with(m, outcome)
    }
}

/// Same level-restricted DFS as seq::hk (duplicated privately to keep the
/// two algorithms independently readable; both are covered by tests).
fn level_dfs(
    g: &BipartiteCsr,
    m: &mut Matching,
    dist: &[i32],
    row_visited: &mut [bool],
    ptr: &mut [u32],
    c0: usize,
    stats: &mut RunStats,
) -> bool {
    let mut col_stack: Vec<u32> = vec![c0 as u32];
    let mut row_stack: Vec<u32> = Vec::new();
    while let Some(&c) = col_stack.last() {
        let c = c as usize;
        let mut advanced = false;
        while ptr[c] < g.cxadj[c + 1] {
            let r = g.cadj[ptr[c] as usize] as usize;
            ptr[c] += 1;
            stats.edges_scanned += 1;
            if row_visited[r] {
                continue;
            }
            let rm = m.rmatch[r];
            if rm == UNMATCHED {
                row_visited[r] = true;
                row_stack.push(r as u32);
                for i in (0..col_stack.len()).rev() {
                    let (ci, ri) = (col_stack[i] as usize, row_stack[i] as usize);
                    m.rmatch[ri] = ci as i32;
                    m.cmatch[ci] = ri as i32;
                }
                return true;
            }
            let c2 = rm as usize;
            if dist[c2] == dist[c] + 1 {
                // level-edge consumption only (see seq::hk::dfs_augment)
                row_visited[r] = true;
                row_stack.push(r as u32);
                col_stack.push(c2 as u32);
                advanced = true;
                break;
            }
        }
        if !advanced {
            col_stack.pop();
            row_stack.pop();
        }
    }
    false
}

/// Unrestricted alternating DFS from an unmatched row: row → free column?
/// done; row → matched column → its row, recurse. Disjointness via
/// col_visited marks shared across the whole Duff–Wiberg pass.
fn row_dfs(
    g: &BipartiteCsr,
    m: &mut Matching,
    col_visited: &mut [bool],
    rptr: &mut [u32],
    r0: usize,
    stats: &mut RunStats,
) -> bool {
    let mut row_stack: Vec<u32> = vec![r0 as u32];
    let mut col_stack: Vec<u32> = Vec::new();
    while let Some(&r) = row_stack.last() {
        let r = r as usize;
        let mut advanced = false;
        while rptr[r] < g.rxadj[r + 1] {
            let c = g.radj[rptr[r] as usize] as usize;
            rptr[r] += 1;
            stats.edges_scanned += 1;
            if col_visited[c] {
                continue;
            }
            col_visited[c] = true;
            let cm = m.cmatch[c];
            if cm == UNMATCHED {
                col_stack.push(c as u32);
                for i in (0..row_stack.len()).rev() {
                    let (ri, ci) = (row_stack[i] as usize, col_stack[i] as usize);
                    m.rmatch[ri] = ci as i32;
                    m.cmatch[ci] = ri as i32;
                }
                return true;
            }
            let r2 = cm as usize;
            col_stack.push(c as u32);
            row_stack.push(r2 as u32);
            advanced = true;
            break;
        }
        if !advanced {
            row_stack.pop();
            col_stack.pop();
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn hkdw_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = Hkdw.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn hkdw_converges_in_fewer_or_equal_phases_than_hk() {
        // the DW pass can only help: phases(HKDW) <= phases(HK)
        for fam in [crate::graph::gen::Family::Delaunay, crate::graph::gen::Family::Social] {
            let g = fam.generate(900, 3);
            let init = InitHeuristic::Cheap.run(&g);
            let hk = super::super::hk::Hk.run_detached(&g, init.clone());
            let dw = Hkdw.run_detached(&g, init);
            assert!(
                dw.stats.phases <= hk.stats.phases,
                "{}: hkdw {} > hk {}",
                fam.name(),
                dw.stats.phases,
                hk.stats.phases
            );
            assert_eq!(dw.matching.cardinality(), hk.matching.cardinality());
        }
    }

    #[test]
    fn prop_hkdw_matches_reference() {
        forall(Config::cases(40), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            let r = Hkdw.run_detached(&g, Matching::empty(nr, nc));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            if r.matching.cardinality() != reference_max_cardinality(&g) {
                return Err("hkdw suboptimal".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_hkdw_with_inits() {
        forall(Config::cases(20), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            let r = Hkdw.run_detached(&g, InitHeuristic::KarpSipser.run(&g));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            if r.matching.cardinality() != reference_max_cardinality(&g) {
                return Err("hkdw+ks suboptimal".into());
            }
            Ok(())
        });
    }
}
