//! PFP — Pothen–Fan with fairness (the paper's sequential "PFP" baseline,
//! from Duff, Kaya & Uçar's matchmaker [8]).
//!
//! Each phase runs disjoint DFS+lookahead searches from every unmatched
//! column; "fairness" alternates the adjacency-scan direction between
//! phases, which empirically prevents adversarial orderings from repeatedly
//! steering the DFS into the same bad corner. The lookahead pointer scans
//! each column's list for a *free* row at most once over the whole run.

use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunOutcome, RunResult, RunStats};
use crate::matching::{Matching, UNMATCHED};

pub struct Pfp;

impl MatchingAlgorithm for Pfp {
    fn name(&self) -> String {
        "pfp".into()
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let mut m = init;
        // lookahead pointers persist across the whole run (amortized O(τ))
        let mut look = ctx.lease_u32(g.nc, 0);
        for c in 0..g.nc {
            look[c] = g.cxadj[c];
        }
        let mut visited = ctx.lease_u32(g.nr, u32::MAX);
        let mut stamp = 0u32;
        let mut forward = true;
        let mut outcome = RunOutcome::Complete;
        loop {
            if let Some(trip) = ctx.checkpoint() {
                outcome = trip;
                break;
            }
            let mut augmented_this_phase = 0u64;
            let mut unmatched_remaining = 0u64;
            for c0 in 0..g.nc {
                if m.cmatch[c0] != UNMATCHED || g.col_degree(c0) == 0 {
                    continue;
                }
                stamp = stamp.wrapping_add(1);
                if dfs_lookahead(
                    g, &mut m, &mut look, &mut visited, stamp, c0, forward, &mut ctx.stats,
                ) {
                    augmented_this_phase += 1;
                    ctx.stats.augmentations += 1;
                } else {
                    unmatched_remaining += 1;
                }
            }
            ctx.record_phase(0); // PFP has no BFS kernels; phases only
            if augmented_this_phase == 0 || unmatched_remaining == 0 {
                break;
            }
            forward = !forward; // fairness: flip scan direction
        }
        ctx.give_u32(look);
        ctx.give_u32(visited);
        ctx.finish_with(m, outcome)
    }
}

/// Iterative DFS with lookahead from unmatched column `c0`. `visited` is
/// stamped per-search (not per-phase): PFP searches within one phase are
/// *not* disjoint — each search may revisit rows freed... they are never
/// freed; stamping per search keeps each search O(τ) while letting later
/// searches in the same phase use rows earlier searches merely traversed.
fn dfs_lookahead(
    g: &BipartiteCsr,
    m: &mut Matching,
    look: &mut [u32],
    visited: &mut [u32],
    stamp: u32,
    c0: usize,
    forward: bool,
    stats: &mut RunStats,
) -> bool {
    let mut col_stack: Vec<u32> = vec![c0 as u32];
    let mut row_stack: Vec<u32> = Vec::new();
    // per-search DFS pointers: store scan offset per depth to keep memory
    // O(path); fairness flips index arithmetic instead of copying the list.
    let mut ptr_stack: Vec<u32> = vec![0];

    while let Some(&c) = col_stack.last() {
        let c = c as usize;
        let deg = g.col_degree(c);

        // 1) lookahead: advance the persistent pointer hunting a free row
        let mut found_free: Option<usize> = None;
        while look[c] < g.cxadj[c + 1] {
            let r = g.cadj[look[c] as usize] as usize;
            look[c] += 1;
            stats.edges_scanned += 1;
            if m.rmatch[r] == UNMATCHED {
                found_free = Some(r);
                break;
            }
        }
        if let Some(r) = found_free {
            // augment along the stack + (c, r)
            row_stack.push(r as u32);
            for i in (0..col_stack.len()).rev() {
                let (ci, ri) = (col_stack[i] as usize, row_stack[i] as usize);
                m.rmatch[ri] = ci as i32;
                m.cmatch[ci] = ri as i32;
            }
            return true;
        }

        // 2) regular DFS step over matched rows
        let mut advanced = false;
        let base = g.cxadj[c];
        while (*ptr_stack.last().unwrap() as usize) < deg {
            let k = *ptr_stack.last().unwrap() as usize;
            *ptr_stack.last_mut().unwrap() += 1;
            let idx = if forward { k } else { deg - 1 - k };
            let r = g.cadj[base as usize + idx] as usize;
            stats.edges_scanned += 1;
            if visited[r] == stamp {
                continue;
            }
            visited[r] = stamp;
            let rm = m.rmatch[r];
            if rm == UNMATCHED {
                // possible if another branch freed nothing — rows never get
                // freed mid-search, but lookahead pointer may have passed a
                // row that was matched then and is... matches only grow, so
                // an unmatched row here was simply beyond the lookahead
                // pointer. Take it.
                row_stack.push(r as u32);
                for i in (0..col_stack.len()).rev() {
                    let (ci, ri) = (col_stack[i] as usize, row_stack[i] as usize);
                    m.rmatch[ri] = ci as i32;
                    m.cmatch[ci] = ri as i32;
                }
                return true;
            }
            let c2 = rm as usize;
            row_stack.push(r as u32);
            col_stack.push(c2 as u32);
            ptr_stack.push(0);
            advanced = true;
            break;
        }
        if !advanced {
            col_stack.pop();
            row_stack.pop();
            ptr_stack.pop();
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn pfp_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = Pfp.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn pfp_lookahead_fast_on_banded() {
        // Hamrle-like banded matrices are PFP's best case in the paper
        // (0.04 s vs 12.61 s for HK); sanity: it must still be optimal.
        let g = crate::graph::gen::banded(2000, 12, 0.4, 5);
        let init = InitHeuristic::Cheap.run(&g);
        let r = Pfp.run_detached(&g, init);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn prop_pfp_matches_reference() {
        forall(Config::cases(40), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            let r = Pfp.run_detached(&g, Matching::empty(nr, nc));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            if r.matching.cardinality() != reference_max_cardinality(&g) {
                return Err(format!(
                    "pfp {} != ref {}",
                    r.matching.cardinality(),
                    reference_max_cardinality(&g)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pfp_with_inits() {
        forall(Config::cases(25), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            for h in [InitHeuristic::Cheap, InitHeuristic::KarpSipser] {
                let r = Pfp.run_detached(&g, h.run(&g));
                r.matching.certify(&g).map_err(|e| e.to_string())?;
                if r.matching.cardinality() != reference_max_cardinality(&g) {
                    return Err("pfp suboptimal with init".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pfp_long_path_no_stack_overflow() {
        let n = 10_000;
        let mut edges = Vec::new();
        for i in 0..n {
            edges.push((i as u32, i as u32));
            if i + 1 < n {
                edges.push((i as u32, i as u32 + 1));
            }
        }
        let g = from_edges(n, n, &edges);
        let r = Pfp.run_detached(&g, Matching::empty(n, n));
        assert_eq!(r.matching.cardinality(), n);
    }
}
