//! MC21-style sequential DFS matcher with lookahead (Duff's classic
//! transversal algorithm) — an extra baseline from the augmenting-path
//! family; single pass over columns, O(n·τ) worst case.

use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunOutcome, RunResult, RunStats};
use crate::matching::{Matching, UNMATCHED};

/// Deadline/cancellation check cadence for the single-pass matchers: the
/// context is consulted every this-many column searches (an inter-"phase"
/// granularity — never inside a search).
pub(crate) const CHECKPOINT_MASK: usize = 1023;

pub struct DfsLookahead;

impl MatchingAlgorithm for DfsLookahead {
    fn name(&self) -> String {
        "dfs".into()
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let mut m = init;
        let mut look = ctx.lease_u32(g.nc, 0);
        for c in 0..g.nc {
            look[c] = g.cxadj[c];
        }
        let mut visited = ctx.lease_u32(g.nr, u32::MAX);
        let mut stamp = 0u32;
        let mut outcome = RunOutcome::Complete;
        for c0 in 0..g.nc {
            if (c0 & CHECKPOINT_MASK) == 0 {
                if let Some(trip) = ctx.checkpoint() {
                    outcome = trip;
                    break;
                }
            }
            if m.cmatch[c0] != UNMATCHED || g.col_degree(c0) == 0 {
                continue;
            }
            stamp = stamp.wrapping_add(1);
            if search(g, &mut m, &mut look, &mut visited, stamp, c0, &mut ctx.stats) {
                ctx.stats.augmentations += 1;
            }
        }
        ctx.record_phase(0);
        ctx.give_u32(look);
        ctx.give_u32(visited);
        ctx.finish_with(m, outcome)
    }
}

fn search(
    g: &BipartiteCsr,
    m: &mut Matching,
    look: &mut [u32],
    visited: &mut [u32],
    stamp: u32,
    c0: usize,
    stats: &mut RunStats,
) -> bool {
    let mut col_stack: Vec<u32> = vec![c0 as u32];
    let mut row_stack: Vec<u32> = Vec::new();
    let mut ptr_stack: Vec<u32> = vec![g.cxadj[c0]];

    while let Some(&c) = col_stack.last() {
        let c = c as usize;
        // lookahead for a free row (persistent pointer)
        let mut free_row = None;
        while look[c] < g.cxadj[c + 1] {
            let r = g.cadj[look[c] as usize] as usize;
            look[c] += 1;
            stats.edges_scanned += 1;
            if m.rmatch[r] == UNMATCHED {
                free_row = Some(r);
                break;
            }
        }
        if let Some(r) = free_row {
            row_stack.push(r as u32);
            for i in (0..col_stack.len()).rev() {
                m.rmatch[row_stack[i] as usize] = col_stack[i] as i32;
                m.cmatch[col_stack[i] as usize] = row_stack[i] as i32;
            }
            return true;
        }
        // DFS over matched rows
        let mut advanced = false;
        while *ptr_stack.last().unwrap() < g.cxadj[c + 1] {
            let r = g.cadj[*ptr_stack.last().unwrap() as usize] as usize;
            *ptr_stack.last_mut().unwrap() += 1;
            stats.edges_scanned += 1;
            if visited[r] == stamp {
                continue;
            }
            visited[r] = stamp;
            let rm = m.rmatch[r];
            if rm == UNMATCHED {
                row_stack.push(r as u32);
                for i in (0..col_stack.len()).rev() {
                    m.rmatch[row_stack[i] as usize] = col_stack[i] as i32;
                    m.cmatch[col_stack[i] as usize] = row_stack[i] as i32;
                }
                return true;
            }
            let c2 = rm as usize;
            row_stack.push(r as u32);
            col_stack.push(c2 as u32);
            ptr_stack.push(g.cxadj[c2]);
            advanced = true;
            break;
        }
        if !advanced {
            col_stack.pop();
            row_stack.pop();
            ptr_stack.pop();
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn dfs_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = DfsLookahead.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn prop_dfs_matches_reference() {
        forall(Config::cases(40), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            let r = DfsLookahead.run_detached(&g, Matching::empty(nr, nc));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            if r.matching.cardinality() != reference_max_cardinality(&g) {
                return Err("dfs suboptimal".into());
            }
            Ok(())
        });
    }

    #[test]
    fn dfs_long_path_iterative() {
        let n = 10_000;
        let mut edges = Vec::new();
        for i in 0..n {
            edges.push((i as u32, i as u32));
            if i + 1 < n {
                edges.push((i as u32, i as u32 + 1));
            }
        }
        let g = from_edges(n, n, &edges);
        let r = DfsLookahead.run_detached(&g, Matching::empty(n, n));
        assert_eq!(r.matching.cardinality(), n);
    }
}
