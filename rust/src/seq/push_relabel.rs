//! Push–relabel matcher — the second algorithm class the paper surveys
//! (Goldberg–Tarjan [12]; bipartite-matching specialization per
//! Goldberg–Kennedy [11] and Kaya–Langguth–Manne–Uçar [16]).
//!
//! FIFO active-column discipline with the *double push* rule: a free column
//! pushes to its minimum-labeled neighbor row (evicting that row's current
//! column, which re-enters the queue) and relabels the row to
//! `second_min + 1`. A column whose minimum neighbor label reaches the
//! label bound is provably unmatchable and is dropped.

use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunOutcome, RunResult};
use crate::matching::{Matching, UNMATCHED};
use std::collections::VecDeque;

pub struct PushRelabel;

impl MatchingAlgorithm for PushRelabel {
    fn name(&self) -> String {
        "pr".into()
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let mut m = init;
        // label bound: no simple alternating path is longer than nr+nc
        let limit: u64 = (g.nr + g.nc + 1) as u64;
        let mut label = vec![0u64; g.nr];
        let mut q: VecDeque<u32> = (0..g.nc)
            .filter(|&c| m.cmatch[c] == UNMATCHED && g.col_degree(c) > 0)
            .map(|c| c as u32)
            .collect();

        let mut outcome = RunOutcome::Complete;
        let mut pops = 0usize;
        while let Some(c) = q.pop_front() {
            // the queue discipline has no phases; checkpoint every batch
            // of pushes instead (matching stays consistent pair-wise)
            if (pops & super::dfs::CHECKPOINT_MASK) == 0 {
                if let Some(trip) = ctx.checkpoint() {
                    outcome = trip;
                    break;
                }
            }
            pops += 1;
            let c = c as usize;
            debug_assert!(m.cmatch[c] == UNMATCHED);
            // find min and second-min neighbor labels
            let mut min1 = u64::MAX;
            let mut min2 = u64::MAX;
            let mut rmin = usize::MAX;
            for &r in g.col_neighbors(c) {
                ctx.stats.edges_scanned += 1;
                let l = label[r as usize];
                if l < min1 {
                    min2 = min1;
                    min1 = l;
                    rmin = r as usize;
                } else if l < min2 {
                    min2 = l;
                }
            }
            if rmin == usize::MAX || min1 >= limit {
                continue; // unmatchable (or isolated): drop permanently
            }
            // double push: evict current occupant (if any), take the row
            let old = m.rmatch[rmin];
            if old != UNMATCHED {
                m.cmatch[old as usize] = UNMATCHED;
                q.push_back(old as u32);
            } else {
                ctx.stats.augmentations += 1;
            }
            m.rmatch[rmin] = c as i32;
            m.cmatch[c] = rmin as i32;
            // relabel
            label[rmin] = if min2 == u64::MAX { limit } else { min2 } + 1;
            ctx.stats.phases += 1; // count pushes as unit work for reporting
        }
        ctx.finish_with(m, outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn pr_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = PushRelabel.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn pr_deficient_graph() {
        // K_{1,3} from the row side: 3 columns share one row
        let g = from_edges(1, 3, &[(0, 0), (0, 1), (0, 2)]);
        let r = PushRelabel.run_detached(&g, Matching::empty(1, 3));
        assert_eq!(r.matching.cardinality(), 1);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn prop_pr_matches_reference() {
        forall(Config::cases(40), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            let r = PushRelabel.run_detached(&g, Matching::empty(nr, nc));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            if r.matching.cardinality() != reference_max_cardinality(&g) {
                return Err(format!(
                    "pr {} != ref {}",
                    r.matching.cardinality(),
                    reference_max_cardinality(&g)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pr_with_init() {
        forall(Config::cases(20), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            let r = PushRelabel.run_detached(&g, InitHeuristic::Cheap.run(&g));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            if r.matching.cardinality() != reference_max_cardinality(&g) {
                return Err("pr+cheap suboptimal".into());
            }
            Ok(())
        });
    }

    #[test]
    fn pr_on_mesh() {
        let g = crate::graph::gen::delaunay_like(400, 3);
        let r = PushRelabel.run_detached(&g, InitHeuristic::Cheap.run(&g));
        r.matching.certify(&g).unwrap();
        assert_eq!(r.matching.cardinality(), reference_max_cardinality(&g));
    }
}
