//! Plain BFS augmenting-path matcher: for each unmatched column run a BFS
//! to the nearest free row and augment immediately. O(n·τ); the sequential
//! ancestor of the paper's combined-BFS GPU algorithms and the P-DBFS
//! multicore baseline.

use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunOutcome, RunResult};
use crate::matching::{Matching, UNMATCHED};

pub struct BfsSimple;

impl MatchingAlgorithm for BfsSimple {
    fn name(&self) -> String {
        "bfs".into()
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let mut m = init;
        // predecessor[r] = column from which row r was reached
        let mut pred = ctx.lease_i32(g.nr, -1);
        let mut visited = ctx.lease_u32(g.nc, u32::MAX);
        let mut rvisited = ctx.lease_u32(g.nr, u32::MAX);
        let mut frontier = ctx.lease_worklist_u32(g.nc);
        let mut next = ctx.lease_worklist_u32(g.nc);
        let mut stamp = 0u32;
        let mut outcome = RunOutcome::Complete;

        for c0 in 0..g.nc {
            if (c0 & super::dfs::CHECKPOINT_MASK) == 0 {
                if let Some(trip) = ctx.checkpoint() {
                    outcome = trip;
                    break;
                }
            }
            if m.cmatch[c0] != UNMATCHED || g.col_degree(c0) == 0 {
                continue;
            }
            stamp = stamp.wrapping_add(1);
            frontier.clear();
            // `next` may hold leftovers when the previous search broke out
            // of its BFS mid-level; a stale column entering this search's
            // frontier corrupts `pred` into a cyclic chain and the augment
            // walk below never terminates.
            next.clear();
            frontier.push(c0 as u32);
            visited[c0] = stamp;
            let mut endpoint: Option<usize> = None;
            let mut launches = 0u32;
            'bfs: while !frontier.is_empty() {
                launches += 1;
                for &c in &frontier {
                    for &r in g.col_neighbors(c as usize) {
                        let r = r as usize;
                        ctx.stats.edges_scanned += 1;
                        if rvisited[r] == stamp {
                            continue;
                        }
                        rvisited[r] = stamp;
                        pred[r] = c as i32;
                        let rm = m.rmatch[r];
                        if rm == UNMATCHED {
                            endpoint = Some(r);
                            break 'bfs;
                        }
                        let c2 = rm as usize;
                        if visited[c2] != stamp {
                            visited[c2] = stamp;
                            next.push(c2 as u32);
                        }
                    }
                }
                std::mem::swap(&mut frontier, &mut next);
                next.clear();
            }
            ctx.record_phase(launches);
            if let Some(mut r) = endpoint {
                // walk predecessors back to c0, flipping edges
                loop {
                    let c = pred[r] as usize;
                    let prev_r = m.cmatch[c];
                    m.rmatch[r] = c as i32;
                    m.cmatch[c] = r as i32;
                    if prev_r == UNMATCHED {
                        break; // reached the root unmatched column
                    }
                    r = prev_r as usize;
                }
                ctx.stats.augmentations += 1;
            }
        }
        ctx.give_i32(pred);
        ctx.give_u32(visited);
        ctx.give_u32(rvisited);
        ctx.give_u32(frontier);
        ctx.give_u32(next);
        ctx.finish_with(m, outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn bfs_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = BfsSimple.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn bfs_augment_path_flip_is_correct() {
        // c0-r0 matched; c1 adj r0 only... then c1-r0, displacing c0 to r1
        let g = from_edges(2, 2, &[(0, 0), (1, 0), (0, 1)]);
        let mut init = Matching::empty(2, 2);
        init.join(0, 0);
        let r = BfsSimple.run_detached(&g, init);
        assert_eq!(r.matching.cardinality(), 2);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn prop_bfs_matches_reference() {
        forall(Config::cases(40), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            let r = BfsSimple.run_detached(&g, Matching::empty(nr, nc));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            if r.matching.cardinality() != reference_max_cardinality(&g) {
                return Err("bfs suboptimal".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stats_populated() {
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2)]);
        let r = BfsSimple.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.stats.augmentations, 3);
        assert!(r.stats.bfs_kernel_launches >= 3);
    }
}
