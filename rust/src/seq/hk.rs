//! Hopcroft–Karp (the paper's sequential "HK" baseline, [14]).
//!
//! Phases of: (1) one combined BFS from all unmatched columns building the
//! level graph, stopping at the first level that reaches a free row;
//! (2) a maximal set of vertex-disjoint shortest augmenting paths found by
//! DFS restricted to the level graph, each augmented. O(√n·τ) total.
//!
//! The DFS is iterative (mesh instances have augmenting paths of length
//! Θ(√n); recursion would overflow the stack) and uses per-column edge
//! pointers so each phase's DFS is O(τ) amortized.

use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunOutcome, RunResult, RunStats};
use crate::matching::{Matching, UNMATCHED};

pub struct Hk;

const UNREACHED: i32 = i32::MAX;

impl MatchingAlgorithm for Hk {
    fn name(&self) -> String {
        "hk".into()
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        let mut m = init;
        let mut dist = ctx.lease_i32(g.nc, UNREACHED);
        let mut frontier = ctx.lease_worklist_u32(g.nc);
        let mut next = ctx.lease_worklist_u32(g.nc);
        let mut row_visited = ctx.lease_bool(g.nr, false);
        let mut ptr = ctx.lease_u32(g.nc, 0);

        let mut outcome = RunOutcome::Complete;
        loop {
            if let Some(trip) = ctx.checkpoint() {
                outcome = trip;
                break;
            }
            let levels = bfs_levels(g, &m, &mut dist, &mut frontier, &mut next, &mut ctx.stats);
            let Some(_aug_level) = levels else {
                break; // no augmenting path: maximum
            };
            ctx.record_phase(_aug_level + 1);

            // DFS for a maximal set of disjoint shortest augmenting paths
            row_visited.iter_mut().for_each(|v| *v = false);
            for c in 0..g.nc {
                ptr[c] = g.cxadj[c];
            }
            for c0 in 0..g.nc {
                if m.cmatch[c0] != UNMATCHED || dist[c0] != 0 || g.col_degree(c0) == 0 {
                    continue;
                }
                let stats = &mut ctx.stats;
                if dfs_augment(g, &mut m, &dist, &mut row_visited, &mut ptr, c0, stats) {
                    stats.augmentations += 1;
                }
            }
        }
        ctx.give_i32(dist);
        ctx.give_u32(frontier);
        ctx.give_u32(next);
        ctx.give_bool(row_visited);
        ctx.give_u32(ptr);
        ctx.finish_with(m, outcome)
    }
}

/// Combined BFS: fills `dist` over columns; returns the level at which a
/// free row was reached (None if unreachable → matching is maximum).
pub(crate) fn bfs_levels(
    g: &BipartiteCsr,
    m: &Matching,
    dist: &mut [i32],
    frontier: &mut Vec<u32>,
    next: &mut Vec<u32>,
    stats: &mut RunStats,
) -> Option<u32> {
    dist.iter_mut().for_each(|d| *d = UNREACHED);
    frontier.clear();
    next.clear();
    for c in 0..g.nc {
        if m.cmatch[c] == UNMATCHED && g.col_degree(c) > 0 {
            dist[c] = 0;
            frontier.push(c as u32);
        }
    }
    let mut level = 0i32;
    let mut found = false;
    while !frontier.is_empty() && !found {
        for &c in frontier.iter() {
            for &r in g.col_neighbors(c as usize) {
                stats.edges_scanned += 1;
                let rm = m.rmatch[r as usize];
                if rm == UNMATCHED {
                    found = true; // shortest level reached; finish this level
                } else {
                    let c2 = rm as usize;
                    if dist[c2] == UNREACHED {
                        dist[c2] = level + 1;
                        next.push(c2 as u32);
                    }
                }
            }
        }
        std::mem::swap(frontier, next);
        next.clear();
        level += 1;
    }
    if found {
        Some(level as u32 - 1)
    } else {
        None
    }
}

/// Iterative DFS from unmatched column `c0` along the level graph
/// (dist[c2] == dist[c] + 1), claiming unvisited rows; augments in place on
/// success. Returns whether a path was augmented.
fn dfs_augment(
    g: &BipartiteCsr,
    m: &mut Matching,
    dist: &[i32],
    row_visited: &mut [bool],
    ptr: &mut [u32],
    c0: usize,
    stats: &mut RunStats,
) -> bool {
    // stacks hold the current alternating path: col_stack[i] --row_stack[i]--> ...
    let mut col_stack: Vec<u32> = vec![c0 as u32];
    let mut row_stack: Vec<u32> = Vec::new();
    while let Some(&c) = col_stack.last() {
        let c = c as usize;
        let mut advanced = false;
        while ptr[c] < g.cxadj[c + 1] {
            let r = g.cadj[ptr[c] as usize] as usize;
            ptr[c] += 1;
            stats.edges_scanned += 1;
            if row_visited[r] {
                continue;
            }
            let rm = m.rmatch[r];
            if rm == UNMATCHED {
                row_visited[r] = true;
                // augment along (col_stack, row_stack + r)
                row_stack.push(r as u32);
                for i in (0..col_stack.len()).rev() {
                    let (ci, ri) = (col_stack[i] as usize, row_stack[i] as usize);
                    m.rmatch[ri] = ci as i32;
                    m.cmatch[ci] = ri as i32;
                }
                return true;
            }
            let c2 = rm as usize;
            if dist[c2] == dist[c] + 1 {
                // mark visited only when (c, r) is a level-graph edge: a
                // row belongs to level dist[rmatch[r]] and may legwise be
                // entered only from level dist-1 columns — marking it on a
                // failed level check from a *different* level would block
                // the one legitimate user (this exact bug made the outer
                // loop spin; see the uniform-300 regression test).
                row_visited[r] = true;
                row_stack.push(r as u32);
                col_stack.push(c2 as u32);
                advanced = true;
                break;
            }
        }
        if !advanced {
            col_stack.pop();
            row_stack.pop();
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn hk_small_perfect() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let r = Hk.run_detached(&g, Matching::empty(3, 3));
        assert_eq!(r.matching.cardinality(), 3);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn hk_with_cheap_init() {
        let g = crate::graph::gen::Family::Kron.generate(512, 5);
        let init = InitHeuristic::Cheap.run(&g);
        let r = Hk.run_detached(&g, init);
        r.matching.certify(&g).unwrap();
        assert_eq!(r.matching.cardinality(), reference_max_cardinality(&g));
    }

    #[test]
    fn hk_empty_graph() {
        let g = from_edges(4, 4, &[]);
        let r = Hk.run_detached(&g, Matching::empty(4, 4));
        assert_eq!(r.matching.cardinality(), 0);
    }

    #[test]
    fn hk_long_path_no_stack_overflow() {
        // path graph of length 20001: worst case for recursive DFS
        let n = 10_000;
        let mut edges = Vec::new();
        for i in 0..n {
            edges.push((i as u32, i as u32));
            if i + 1 < n {
                edges.push((i as u32, i as u32 + 1));
            }
        }
        let g = from_edges(n, n, &edges);
        let r = Hk.run_detached(&g, Matching::empty(n, n));
        assert_eq!(r.matching.cardinality(), n);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn hk_phase_count_sublinear() {
        // HK's O(sqrt n) phase bound should show: on a 2500-vertex planted
        // instance, far fewer than 50 phases from a cheap init.
        let g = crate::graph::gen::random::with_perfect_matching(2500, 2.0, 9);
        let init = InitHeuristic::Cheap.run(&g);
        let r = Hk.run_detached(&g, init);
        assert!(r.stats.phases <= 51, "phases = {}", r.stats.phases);
        r.matching.certify(&g).unwrap();
    }

    #[test]
    fn hk_honours_cancellation_between_phases() {
        let g = crate::graph::gen::Family::Uniform.generate(400, 2);
        let mut ctx = RunCtx::detached();
        ctx.cancel_token().cancel();
        let r = Hk.run(&g, Matching::empty(g.nr, g.nc), &mut ctx);
        assert_eq!(r.outcome, RunOutcome::Cancelled);
        r.matching.validate(&g).unwrap(); // valid, just not necessarily maximum
        assert_eq!(r.matching.cardinality(), 0, "cancelled before the first phase");
    }

    #[test]
    fn hk_honours_expired_deadline() {
        let g = crate::graph::gen::Family::Uniform.generate(400, 2);
        let mut ctx = RunCtx::detached().with_deadline_in(std::time::Duration::ZERO);
        let r = Hk.run(&g, Matching::empty(g.nr, g.nc), &mut ctx);
        assert_eq!(r.outcome, RunOutcome::DeadlineExceeded);
        r.matching.validate(&g).unwrap();
    }

    #[test]
    fn hk_leases_workspaces_from_the_ctx_pool() {
        let g = crate::graph::gen::Family::Uniform.generate(300, 3);
        let pool = std::sync::Arc::new(crate::util::pool::WorkspacePool::new());
        let r1 = Hk.run(&g, Matching::empty(g.nr, g.nc), &mut RunCtx::new(pool.clone()));
        assert_eq!(pool.reuses(), 0, "first run has nothing to reuse");
        let returned = pool.returns();
        assert!(returned >= 5, "run must give its scratch buffers back");
        let r2 = Hk.run(&g, Matching::empty(g.nr, g.nc), &mut RunCtx::new(pool.clone()));
        assert!(
            pool.reuses() >= 5,
            "second same-size run must lease the first run's buffers, reuses={}",
            pool.reuses()
        );
        assert_eq!(r1.matching.cardinality(), r2.matching.cardinality());
    }

    #[test]
    fn prop_hk_matches_reference() {
        forall(Config::cases(40), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            let r = Hk.run_detached(&g, Matching::empty(nr, nc));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            let want = reference_max_cardinality(&g);
            if r.matching.cardinality() != want {
                return Err(format!("hk {} != ref {want}", r.matching.cardinality()));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_hk_respects_init() {
        forall(Config::cases(25), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            for h in [InitHeuristic::Cheap, InitHeuristic::KarpSipser] {
                let r = Hk.run_detached(&g, h.run(&g));
                r.matching.certify(&g).map_err(|e| format!("{}: {e}", h.name()))?;
                if r.matching.cardinality() != reference_max_cardinality(&g) {
                    return Err("init changed final cardinality".into());
                }
            }
            Ok(())
        });
    }
}

#[cfg(test)]
mod regression_tests {
    use super::*;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;

    /// Regression: a row adjacent to columns at different BFS levels must
    /// stay usable by the level-graph edge even after another level's DFS
    /// scanned (and rejected) it. Before the fix, HK span forever on this
    /// instance (BFS kept finding a path the DFS could never realize).
    #[test]
    fn hk_uniform300_terminates_and_is_optimal() {
        let g = crate::graph::gen::Family::Uniform.generate(300, 1);
        let init = InitHeuristic::Cheap.run(&g);
        let r = Hk.run_detached(&g, init);
        r.matching.certify(&g).unwrap();
        assert_eq!(r.matching.cardinality(), reference_max_cardinality(&g));
    }

    #[test]
    fn hk_uniform_sweep_terminates() {
        for seed in 0..6 {
            let g = crate::graph::gen::uniform_random(400, 400, 4.5, seed);
            let r = Hk.run_detached(&g, InitHeuristic::Cheap.run(&g));
            r.matching.certify(&g).unwrap();
        }
    }
}
