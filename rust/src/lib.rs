//! # bimatch
//!
//! A production-quality reproduction of *"GPU accelerated maximum
//! cardinality matching algorithms for bipartite graphs"* (Deveci, Kaya,
//! Uçar, Çatalyürek — 2013) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — graph substrate, the paper's GPU algorithms
//!   (APFB/APsB × GPUBFS/GPUBFS-WR × CT/MT) on a deterministic device
//!   simulator, sequential (HK, HKDW, PFP, DFS, BFS, push–relabel) and
//!   multicore (P-HK, P-PFP, P-DBFS) baselines, an evaluation harness that
//!   regenerates every table/figure of the paper, and a matching-service
//!   coordinator.
//! * **L2/L1 (python/, build-time only)** — the same level-expansion
//!   kernel as a JAX program with a Pallas kernel inside, AOT-lowered to
//!   HLO text.
//! * **Runtime** — `runtime::Engine` loads the HLO artifacts through the
//!   PJRT CPU client (`xla` crate) so the "GPU" path runs with Python
//!   nowhere on the request path.
//!
//! ## The typed execution API
//!
//! Algorithm dispatch is typed end to end:
//!
//! * [`AlgoSpec`] names a matcher — `Seq(SeqKind)`, `Multicore { kind,
//!   threads }`, `Gpu(GpuConfig)`, `Sharded { inner, shards }`, or
//!   `Xla(XlaKind)`. Its `FromStr`/`Display` impls are the stable
//!   wire/CLI format (`"hk"`, `"p-dbfs@4"`, `"gpu:APFB-GPUBFS-WR-CT-FC"`,
//!   `"shard4:gpu:APFB-GPUBFS-WR-CT-FC"`, `"xla:apfb-full"`),
//!   round-tripping every registry name;
//!   `coordinator::registry::build` turns a spec into a runnable matcher
//!   and `coordinator::router::route` returns one. Configuration edits
//!   (e.g. the frontier-mode override) are typed field edits, not string
//!   surgery.
//! * Every run executes against a [`RunCtx`] carrying what a serving
//!   layer needs: a [`util::pool::WorkspacePool`] (size-keyed scratch
//!   reuse — `bfs_array`/frontier/visited buffers survive across jobs), a
//!   deadline plus a [`CancelToken`] that matchers check **between
//!   phases**, and the [`matching::algo::RunStats`] sink. A tripped run
//!   returns a *valid* (but possibly non-maximum) matching tagged
//!   [`RunOutcome::DeadlineExceeded`] / [`RunOutcome::Cancelled`]; the
//!   coordinator surfaces it as a distinct job failure
//!   (`coordinator::job::JobError`) and the TCP server replies
//!   `ERR timeout: ...` (`MATCH ... timeout_ms=<int>`).
//! * One-shot callers use [`MatchingAlgorithm::run_detached`], which
//!   supplies a throwaway context.
//!
//! ## Layer map
//!
//! `graph` (CSR substrate + generators + MatrixMarket IO) → `matching`
//! (representation, certification, the algorithm trait + `RunCtx`) →
//! matchers (`seq`, `multicore`, `gpu` simulator + `gpu::xla_backend`,
//! `shard` multi-device execution over a modeled interconnect) →
//! `dynamic` (online matching: delta batches over a mutable CSR overlay,
//! seeded incremental repair) → `coordinator` (typed registry/router,
//! executor, worker-pool service, server-side graph store behind the
//! `LOAD`/`UPDATE`/`DROP` verbs, TCP server) — with `harness` (paper
//! tables/figures) and `apps` (BTF) on the side.
//!
//! ## Verifying
//!
//! The tier-1 gate is `cargo build --release && cargo test -q` (run from
//! `rust/`). Registry-name stability is enforced by a golden-file test
//! against `rust/registry-names.txt` and a CI diff of
//! `bimatch --list-algos` output. The opt-in correctness analyzers live
//! in [`sanitize`]: `BIMATCH_SANITIZE=1` arms the device race sanitizer,
//! debug builds arm the lock-order watchdog, and `bimatch fsck
//! --data-dir <dir>` checks durability state offline.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod apps;
pub mod cli;
pub mod coordinator;
pub mod dynamic;
pub mod gpu;
pub mod graph;
pub mod harness;
pub mod matching;
pub mod multicore;
pub mod obs;
pub mod persist;
pub mod runtime;
pub mod sanitize;
pub mod seq;
pub mod shard;
pub mod trace;
pub mod util;

pub use coordinator::spec::{AlgoSpec, MulticoreKind, SeqKind, XlaKind};
pub use matching::algo::{CancelToken, MatchingAlgorithm, RunCtx, RunOutcome, RunResult};
pub use matching::Matching;
pub use util::pool::WorkspacePool;
