//! # bimatch
//!
//! A production-quality reproduction of *"GPU accelerated maximum
//! cardinality matching algorithms for bipartite graphs"* (Deveci, Kaya,
//! Uçar, Çatalyürek — 2013) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — graph substrate, the paper's GPU algorithms
//!   (APFB/APsB × GPUBFS/GPUBFS-WR × CT/MT) on a deterministic device
//!   simulator, sequential (HK, HKDW, PFP, DFS, BFS, push–relabel) and
//!   multicore (P-HK, P-PFP, P-DBFS) baselines, an evaluation harness that
//!   regenerates every table/figure of the paper, and a matching-service
//!   coordinator.
//! * **L2/L1 (python/, build-time only)** — the same level-expansion
//!   kernel as a JAX program with a Pallas kernel inside, AOT-lowered to
//!   HLO text.
//! * **Runtime** — `runtime::Engine` loads the HLO artifacts through the
//!   PJRT CPU client (`xla` crate) so the "GPU" path runs with Python
//!   nowhere on the request path.

pub mod apps;
pub mod cli;
pub mod coordinator;
pub mod gpu;
pub mod graph;
pub mod harness;
pub mod matching;
pub mod multicore;
pub mod runtime;
pub mod seq;
pub mod util;

pub use matching::algo::{MatchingAlgorithm, RunResult};
pub use matching::Matching;
