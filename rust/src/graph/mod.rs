//! Bipartite graph representation, IO, transformations, and synthetic
//! workload generation.

pub mod builder;
pub mod csr;
pub mod ell;
pub mod gen;
pub mod mtx;
pub mod permute;

pub use builder::{from_edges, EdgeList};
pub use csr::BipartiteCsr;
pub use ell::EllGraph;
pub use permute::random_permute;
