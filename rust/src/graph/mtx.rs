//! MatrixMarket coordinate-format reader/writer.
//!
//! The paper evaluates on UFL (SuiteSparse) matrices distributed as `.mtx`
//! files. The collection is not available offline, so the repo ships
//! generators instead — but the IO layer is complete so a user *with* the
//! collection can run the same harness on the real instances
//! (`bimatch run --mtx path/to/matrix.mtx`).
//!
//! Supported: `matrix coordinate {pattern|real|integer|complex}
//! {general|symmetric|skew-symmetric|hermitian}`. Values are ignored — only
//! the nonzero *structure* matters for matching. Symmetric variants emit
//! the mirrored entry (the bipartite row/column classes are distinct, so
//! A[j][i] is a distinct edge).

use super::builder::EdgeList;
use super::csr::BipartiteCsr;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

#[derive(Debug, thiserror::Error)]
pub enum MtxError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("invalid MatrixMarket header: {0}")]
    Header(String),
    #[error("parse error at line {line}: {msg}")]
    Parse { line: usize, msg: String },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Symmetry {
    General,
    Symmetric, // covers skew & hermitian for pattern purposes
}

/// Read a bipartite graph from a MatrixMarket file: rows → row vertices,
/// columns → column vertices, nonzeros → edges.
pub fn read_mtx(path: &Path) -> Result<BipartiteCsr, MtxError> {
    let f = std::fs::File::open(path)?;
    read_mtx_from(BufReader::new(f))
}

/// Reader-generic implementation (unit-testable without touching disk).
pub fn read_mtx_from<R: BufRead>(reader: R) -> Result<BipartiteCsr, MtxError> {
    let mut lines = reader.lines().enumerate();

    // header line
    let (_, header) = lines
        .next()
        .ok_or_else(|| MtxError::Header("empty file".into()))?;
    let header = header?;
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_ascii_lowercase()).collect();
    if h.len() < 4 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        return Err(MtxError::Header(header));
    }
    if h[2] != "coordinate" {
        return Err(MtxError::Header(format!("only coordinate format supported, got {}", h[2])));
    }
    let field = h[3].as_str();
    if !matches!(field, "pattern" | "real" | "integer" | "complex") {
        return Err(MtxError::Header(format!("unsupported field type {field}")));
    }
    let symmetry = match h.get(4).map(|s| s.as_str()).unwrap_or("general") {
        "general" => Symmetry::General,
        "symmetric" | "skew-symmetric" | "hermitian" => Symmetry::Symmetric,
        other => return Err(MtxError::Header(format!("unsupported symmetry {other}"))),
    };

    // size line (skipping comments)
    let mut size_line = None;
    for (ln, line) in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some((ln, t.to_string()));
        break;
    }
    let (size_ln, size_line) =
        size_line.ok_or_else(|| MtxError::Header("missing size line".into()))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|e| MtxError::Parse { line: size_ln + 1, msg: e.to_string() })?;
    if dims.len() != 3 {
        return Err(MtxError::Parse { line: size_ln + 1, msg: "size line needs 3 fields".into() });
    }
    let (nr, nc, nnz) = (dims[0], dims[1], dims[2]);

    let mut el = EdgeList::with_capacity(nr, nc, nnz);
    let mut seen = 0usize;
    for (ln, line) in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let parse = |tok: Option<&str>, ln: usize| -> Result<usize, MtxError> {
            tok.ok_or(MtxError::Parse { line: ln + 1, msg: "missing index".into() })?
                .parse::<usize>()
                .map_err(|e| MtxError::Parse { line: ln + 1, msg: e.to_string() })
        };
        let i = parse(it.next(), ln)?;
        let j = parse(it.next(), ln)?;
        if i == 0 || j == 0 || i > nr || j > nc {
            return Err(MtxError::Parse {
                line: ln + 1,
                msg: format!("index ({i},{j}) out of 1..={nr} x 1..={nc}"),
            });
        }
        el.add(i - 1, j - 1);
        if symmetry == Symmetry::Symmetric && i != j {
            // mirrored structural entry; valid only if square-indexable
            if j <= nr && i <= nc {
                el.add(j - 1, i - 1);
            }
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(MtxError::Parse {
            line: 0,
            msg: format!("expected {nnz} entries, found {seen}"),
        });
    }
    Ok(el.build())
}

/// Write a graph as `pattern general` coordinate MatrixMarket.
pub fn write_mtx(g: &BipartiteCsr, path: &Path) -> Result<(), MtxError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate pattern general")?;
    writeln!(f, "% written by bimatch")?;
    writeln!(f, "{} {} {}", g.nr, g.nc, g.n_edges())?;
    for c in 0..g.nc {
        for &r in g.col_neighbors(c) {
            writeln!(f, "{} {}", r + 1, c + 1)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(s: &str) -> Result<BipartiteCsr, MtxError> {
        read_mtx_from(Cursor::new(s.as_bytes()))
    }

    #[test]
    fn pattern_general() {
        let g = parse(
            "%%MatrixMarket matrix coordinate pattern general\n\
             % a comment\n\
             3 2 3\n\
             1 1\n\
             3 2\n\
             2 1\n",
        )
        .unwrap();
        assert_eq!((g.nr, g.nc, g.n_edges()), (3, 2, 3));
        assert!(g.has_edge(0, 0) && g.has_edge(1, 0) && g.has_edge(2, 1));
    }

    #[test]
    fn real_values_ignored() {
        let g = parse(
            "%%MatrixMarket matrix coordinate real general\n\
             2 2 2\n\
             1 1 3.25\n\
             2 2 -1e-3\n",
        )
        .unwrap();
        assert_eq!(g.n_edges(), 2);
    }

    #[test]
    fn symmetric_mirrors() {
        let g = parse(
            "%%MatrixMarket matrix coordinate pattern symmetric\n\
             3 3 2\n\
             2 1\n\
             3 3\n",
        )
        .unwrap();
        // (2,1) mirrored to (1,2); diagonal (3,3) not duplicated
        assert_eq!(g.n_edges(), 3);
        assert!(g.has_edge(1, 0) && g.has_edge(0, 1) && g.has_edge(2, 2));
    }

    #[test]
    fn bad_header_rejected() {
        assert!(matches!(parse("garbage\n1 1 0\n"), Err(MtxError::Header(_))));
        assert!(matches!(
            parse("%%MatrixMarket matrix array real general\n1 1 1\n1.0\n"),
            Err(MtxError::Header(_))
        ));
    }

    #[test]
    fn out_of_range_rejected() {
        let r = parse(
            "%%MatrixMarket matrix coordinate pattern general\n\
             2 2 1\n\
             3 1\n",
        );
        assert!(matches!(r, Err(MtxError::Parse { .. })));
    }

    #[test]
    fn nnz_mismatch_rejected() {
        let r = parse(
            "%%MatrixMarket matrix coordinate pattern general\n\
             2 2 2\n\
             1 1\n",
        );
        assert!(matches!(r, Err(MtxError::Parse { .. })));
    }

    #[test]
    fn write_read_roundtrip() {
        let g = crate::graph::builder::from_edges(4, 3, &[(0, 0), (1, 2), (3, 1), (2, 2)]);
        let dir = std::env::temp_dir().join("bimatch_mtx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.mtx");
        write_mtx(&g, &path).unwrap();
        let g2 = read_mtx(&path).unwrap();
        assert_eq!(g, g2);
    }
}
