//! Edge-list (COO) accumulation and conversion to [`BipartiteCsr`].
//! Accepts unsorted input with duplicates; dedups on build.

use super::csr::BipartiteCsr;

/// Mutable edge accumulator.
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    nr: usize,
    nc: usize,
    edges: Vec<(u32, u32)>,
}

impl EdgeList {
    pub fn new(nr: usize, nc: usize) -> Self {
        assert!(nr <= u32::MAX as usize && nc <= u32::MAX as usize);
        Self { nr, nc, edges: Vec::new() }
    }

    pub fn with_capacity(nr: usize, nc: usize, cap: usize) -> Self {
        let mut e = Self::new(nr, nc);
        e.edges.reserve(cap);
        e
    }

    /// Add edge (row r, column c). Out-of-range edges panic in debug and
    /// are rejected with an assert in release too — generators must be
    /// in-bounds by construction.
    #[inline]
    pub fn add(&mut self, r: usize, c: usize) {
        assert!(r < self.nr && c < self.nc, "edge ({r},{c}) out of {}x{}", self.nr, self.nc);
        self.edges.push((r as u32, c as u32));
    }

    pub fn len(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    pub fn nr(&self) -> usize {
        self.nr
    }

    pub fn nc(&self) -> usize {
        self.nc
    }

    /// Build the CSR graph: sort column-major, dedup, compress.
    pub fn build(mut self) -> BipartiteCsr {
        // sort by (c, r) so cadj comes out sorted per column
        self.edges.sort_unstable_by_key(|&(r, c)| (c, r));
        self.edges.dedup();
        let mut cxadj = vec![0u32; self.nc + 1];
        for &(_, c) in &self.edges {
            cxadj[c as usize + 1] += 1;
        }
        for i in 0..self.nc {
            cxadj[i + 1] += cxadj[i];
        }
        let cadj: Vec<u32> = self.edges.iter().map(|&(r, _)| r).collect();
        BipartiteCsr::from_col_csr(self.nr, self.nc, cxadj, cadj)
    }
}

/// Convenience: build a graph straight from an edge slice.
pub fn from_edges(nr: usize, nc: usize, edges: &[(u32, u32)]) -> BipartiteCsr {
    let mut el = EdgeList::with_capacity(nr, nc, edges.len());
    for &(r, c) in edges {
        el.add(r as usize, c as usize);
    }
    el.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn dedup_and_sort() {
        let g = from_edges(3, 2, &[(2, 1), (0, 0), (2, 1), (1, 0), (0, 0)]);
        assert_eq!(g.n_edges(), 3);
        assert_eq!(g.col_neighbors(0), &[0, 1]);
        assert_eq!(g.col_neighbors(1), &[2]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn empty_build() {
        let g = EdgeList::new(4, 5).build();
        assert_eq!(g.nr, 4);
        assert_eq!(g.nc, 5);
        assert_eq!(g.n_edges(), 0);
        assert!(g.validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn out_of_bounds_panics() {
        let mut el = EdgeList::new(2, 2);
        el.add(2, 0);
    }

    #[test]
    fn prop_build_roundtrips_edge_set() {
        forall(Config::cases(40), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 40);
            let g = from_edges(nr, nc, &edges);
            g.validate().map_err(|e| format!("invalid: {e}"))?;
            let mut got = g.edges();
            got.sort_unstable();
            let mut want = edges.clone();
            want.sort_unstable();
            if got != want {
                return Err(format!("edge set mismatch: {} vs {}", got.len(), want.len()));
            }
            Ok(())
        });
    }
}
