//! CSR → ELL packing for the XLA/PJRT backend.
//!
//! The Pallas/JAX formulation of the BFS level kernel (L1/L2) operates on a
//! dense `(nc, K)` neighbor table — the TPU analogue of the paper's
//! coalesced CUDA loads (see DESIGN.md §Hardware-Adaptation). Columns with
//! degree > K are split into *replica* columns that share the original
//! column's identity via `owner`; padding slots hold -1. Shapes are rounded
//! up to the compiled artifact buckets.

use super::csr::BipartiteCsr;

/// ELL-packed bipartite graph, padded to fixed (possibly bucketed) shape.
#[derive(Debug, Clone)]
pub struct EllGraph {
    /// logical sizes
    pub nr: usize,
    pub nc: usize,
    /// padded sizes (artifact bucket)
    pub nc_pad: usize,
    pub nr_pad: usize,
    /// neighbors per packed column
    pub k: usize,
    /// row ids, shape (nc_pad, k) row-major, -1 = empty slot
    pub adj: Vec<i32>,
    /// owner[packed_col] = logical column this packed column belongs to,
    /// -1 for pure padding. A column of degree d occupies ceil(d/k)
    /// consecutive packed slots.
    pub owner: Vec<i32>,
}

impl EllGraph {
    /// Pack with the given K; no bucket padding (nc_pad = #packed cols,
    /// nr_pad = nr).
    pub fn pack(g: &BipartiteCsr, k: usize) -> Self {
        assert!(k >= 1);
        // count packed columns: degree-0 columns still occupy one slot so
        // the owner map stays total.
        let mut packed_cols = 0usize;
        for c in 0..g.nc {
            packed_cols += g.col_degree(c).div_ceil(k).max(1);
        }
        let mut adj = vec![-1i32; packed_cols * k];
        let mut owner = vec![-1i32; packed_cols];
        let mut slot = 0usize;
        for c in 0..g.nc {
            let nbrs = g.col_neighbors(c);
            let nslots = nbrs.len().div_ceil(k).max(1);
            for s in 0..nslots {
                owner[slot] = c as i32;
                let base = slot * k;
                for j in 0..k {
                    let idx = s * k + j;
                    if idx < nbrs.len() {
                        adj[base + j] = nbrs[idx] as i32;
                    }
                }
                slot += 1;
            }
        }
        debug_assert_eq!(slot, packed_cols);
        Self { nr: g.nr, nc: g.nc, nc_pad: packed_cols, nr_pad: g.nr, k, adj, owner }
    }

    /// Pack and pad up to a compiled bucket shape (nc_bucket, nr_bucket, k).
    /// Returns None if the graph does not fit the bucket.
    pub fn pack_bucketed(
        g: &BipartiteCsr,
        nc_bucket: usize,
        nr_bucket: usize,
        k: usize,
    ) -> Option<Self> {
        let mut e = Self::pack(g, k);
        if e.nc_pad > nc_bucket || e.nr > nr_bucket || g.nc > nc_bucket {
            return None;
        }
        e.adj.resize(nc_bucket * k, -1);
        e.owner.resize(nc_bucket, -1);
        e.nc_pad = nc_bucket;
        e.nr_pad = nr_bucket;
        Some(e)
    }

    /// Number of non-padding slots (must equal the edge count).
    pub fn n_edges(&self) -> usize {
        self.adj.iter().filter(|&&v| v >= 0).count()
    }

    /// Neighbors in packed slot `s`.
    pub fn slot(&self, s: usize) -> &[i32] {
        &self.adj[s * self.k..(s + 1) * self.k]
    }

    /// Recover the edge list (r, logical c) — for validation.
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.n_edges());
        for s in 0..self.nc_pad {
            let c = self.owner[s];
            if c < 0 {
                continue;
            }
            for &r in self.slot(s) {
                if r >= 0 {
                    out.push((r as u32, c as u32));
                }
            }
        }
        out.sort_unstable_by_key(|&(r, c)| (c, r));
        out
    }
}

/// Choose a K for a graph: a power of two ≥ a high-degree quantile so most
/// columns fit one slot, capped to keep the dense table small.
pub fn suggest_k(g: &BipartiteCsr, cap: usize) -> usize {
    if g.nc == 0 {
        return 1;
    }
    let mut degs: Vec<usize> = (0..g.nc).map(|c| g.col_degree(c)).collect();
    degs.sort_unstable();
    let q95 = degs[(g.nc as f64 * 0.95) as usize % g.nc].max(1);
    let mut k = 1usize;
    while k < q95 {
        k <<= 1;
    }
    k.min(cap.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_edges;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn pack_simple() {
        let g = from_edges(3, 2, &[(0, 0), (1, 0), (2, 0), (1, 1)]);
        let e = EllGraph::pack(&g, 2);
        // col0 deg 3 -> 2 slots; col1 deg 1 -> 1 slot
        assert_eq!(e.nc_pad, 3);
        assert_eq!(e.owner, vec![0, 0, 1]);
        assert_eq!(e.slot(0), &[0, 1]);
        assert_eq!(e.slot(1), &[2, -1]);
        assert_eq!(e.slot(2), &[1, -1]);
        assert_eq!(e.n_edges(), 4);
    }

    #[test]
    fn degree_zero_columns_keep_slots() {
        let g = from_edges(2, 3, &[(0, 2)]);
        let e = EllGraph::pack(&g, 4);
        assert_eq!(e.nc_pad, 3);
        assert_eq!(e.owner, vec![0, 1, 2]);
        assert_eq!(e.n_edges(), 1);
    }

    #[test]
    fn bucket_padding() {
        let g = from_edges(3, 2, &[(0, 0), (2, 1)]);
        let e = EllGraph::pack_bucketed(&g, 8, 16, 2).unwrap();
        assert_eq!(e.nc_pad, 8);
        assert_eq!(e.nr_pad, 16);
        assert_eq!(e.adj.len(), 16);
        assert_eq!(e.owner.len(), 8);
        assert_eq!(e.n_edges(), 2);
        // too-small bucket is rejected
        assert!(EllGraph::pack_bucketed(&g, 1, 16, 2).is_none());
        assert!(EllGraph::pack_bucketed(&g, 8, 2, 2).is_none());
    }

    #[test]
    fn prop_pack_preserves_edges() {
        forall(Config::cases(30), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            for k in [1usize, 2, 5] {
                let e = EllGraph::pack(&g, k);
                let mut want = g.edges();
                want.sort_unstable_by_key(|&(r, c)| (c, r));
                if e.edges() != want {
                    return Err(format!("k={k}: edges differ after pack"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn suggest_k_reasonable() {
        let g = from_edges(10, 4, &[(0, 0), (1, 0), (2, 0), (3, 0), (0, 1), (1, 2), (2, 3)]);
        let k = suggest_k(&g, 64);
        assert!(k.is_power_of_two());
        assert!(k <= 64 && k >= 1);
    }
}
