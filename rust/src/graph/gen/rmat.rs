//! RMAT / Kronecker generator (kron_g500-logn21 stand-in): recursive
//! quadrant descent with probabilities (a, b, c, d). Produces the heavily
//! skewed degree distribution on which the paper's GPU algorithm shows the
//! largest wins over DFS-based sequential codes.

use crate::graph::builder::EdgeList;
use crate::graph::csr::BipartiteCsr;
use crate::util::rng::Xoshiro256;

/// `n` is rounded up to a power of two; `edges_per_vertex` scales the edge
/// count; `(a, b, c)` are the RMAT quadrant probabilities (d = 1-a-b-c).
pub fn rmat(n: usize, edges_per_vertex: usize, abc: (f64, f64, f64), seed: u64) -> BipartiteCsr {
    let scale = (n.max(2) as f64).log2().ceil() as u32;
    let nv = 1usize << scale;
    let (a, b, c) = abc;
    let d = 1.0 - a - b - c;
    assert!(d >= 0.0 && a >= 0.0 && b >= 0.0 && c >= 0.0, "bad RMAT probabilities");
    let m = nv * edges_per_vertex;
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::with_capacity(nv, nv, m + nv);
    for v in 0..nv {
        // sparse diagonal: enough structure to look like a kron matrix,
        // not enough for the greedy init to trivially complete
        if rng.gen_bool(0.25) {
            el.add(v, v);
        }
    }
    for _ in 0..m {
        let (mut r, mut cidx) = (0usize, 0usize);
        for level in (0..scale).rev() {
            let p = rng.next_f64();
            // noise the quadrant probabilities slightly per level to avoid
            // the well-known RMAT self-similarity artifacts
            let (qa, qb, qc) = (a, b, c);
            let bit = 1usize << level;
            if p < qa {
                // top-left: nothing
            } else if p < qa + qb {
                cidx |= bit;
            } else if p < qa + qb + qc {
                r |= bit;
            } else {
                r |= bit;
                cidx |= bit;
            }
        }
        el.add(r, cidx);
    }
    el.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_shapes() {
        let g = rmat(1000, 4, (0.57, 0.19, 0.19), 7);
        assert_eq!(g.nr, 1024);
        assert!(g.validate().is_ok());
        assert!(g.n_edges() > 1024); // diagonal + off-diagonals (dedup'd)
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(2048, 8, (0.57, 0.19, 0.19), 21);
        // skew: max degree far above average
        let avg = g.avg_col_degree();
        let max = g.max_col_degree() as f64;
        assert!(max > 4.0 * avg, "max {max} vs avg {avg} — not skewed enough");
    }

    #[test]
    fn uniform_probabilities_not_skewed() {
        let g = rmat(2048, 8, (0.25, 0.25, 0.25), 21);
        let avg = g.avg_col_degree();
        let max = g.max_col_degree() as f64;
        assert!(max < 6.0 * avg, "uniform rmat should be flat: max {max} avg {avg}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            rmat(512, 4, (0.57, 0.19, 0.19), 3),
            rmat(512, 4, (0.57, 0.19, 0.19), 3)
        );
    }

    #[test]
    #[should_panic(expected = "bad RMAT")]
    fn rejects_bad_probs() {
        rmat(64, 2, (0.6, 0.3, 0.3), 1);
    }
}
