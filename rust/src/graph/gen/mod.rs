//! Synthetic workload generators.
//!
//! The paper evaluates on 70 matrices from the UFL (SuiteSparse) collection
//! which is not available offline; each generator here is a seeded,
//! structure-faithful stand-in for one of the paper's instance *classes*
//! (DESIGN.md §2 documents the substitution). All generators:
//!
//! * produce the bipartite row/column graph of a sparse square matrix
//!   pattern (the paper's setting),
//! * are deterministic in `(params, seed)`,
//! * return a validated [`BipartiteCsr`].

pub mod banded;
pub mod geometric;
pub mod mesh;
pub mod powerlaw;
pub mod random;
pub mod rmat;

pub use banded::banded;
pub use geometric::rgg;
pub use mesh::{delaunay_like, grid_road, hugetrace};
pub use powerlaw::{chung_lu, pref_attach, web_graph};
pub use random::uniform_random;
pub use rmat::rmat;

use super::csr::BipartiteCsr;

/// A named generator family, so the harness catalog can enumerate them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// road-network-like: sparse planar grid with deletions (roadNet-CA)
    Road,
    /// triangulation-like mesh (delaunay_nXX)
    Delaunay,
    /// long thin perforated mesh (hugetrace / hugebubbles)
    HugeTrace,
    /// random geometric graph (rgg_n_2_24_s0)
    Rgg,
    /// Kronecker / RMAT power-law (kron_g500-logn21)
    Kron,
    /// Chung–Lu power-law (as-Skitter / soc-LiveJournal-ish)
    Social,
    /// preferential attachment, low degree (amazon co-purchase)
    Amazon,
    /// locality-biased power-law web graph (wb-edu / wikipedia)
    Web,
    /// banded with irregular fill (Hamrle3)
    Banded,
    /// uniform random (control)
    Uniform,
}

impl Family {
    pub const ALL: [Family; 10] = [
        Family::Road,
        Family::Delaunay,
        Family::HugeTrace,
        Family::Rgg,
        Family::Kron,
        Family::Social,
        Family::Amazon,
        Family::Web,
        Family::Banded,
        Family::Uniform,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Family::Road => "road",
            Family::Delaunay => "delaunay",
            Family::HugeTrace => "hugetrace",
            Family::Rgg => "rgg",
            Family::Kron => "kron",
            Family::Social => "social",
            Family::Amazon => "amazon",
            Family::Web => "web",
            Family::Banded => "banded",
            Family::Uniform => "uniform",
        }
    }

    pub fn from_name(s: &str) -> Option<Family> {
        Family::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// Generate an instance with roughly `n` vertices per side.
    pub fn generate(&self, n: usize, seed: u64) -> BipartiteCsr {
        match self {
            Family::Road => grid_road(n, 0.12, seed),
            Family::Delaunay => delaunay_like(n, seed),
            Family::HugeTrace => hugetrace(n, 0.08, seed),
            Family::Rgg => rgg(n, 2.2, seed),
            Family::Kron => rmat(n, 8, (0.57, 0.19, 0.19), seed),
            Family::Social => chung_lu(n, 8.0, 2.3, seed),
            Family::Amazon => pref_attach(n, 3, seed),
            Family::Web => web_graph(n, 6.0, seed),
            Family::Banded => banded(n, 24, 0.35, seed),
            Family::Uniform => uniform_random(n, n, 5.0, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_families_generate_valid_graphs() {
        for fam in Family::ALL {
            let g = fam.generate(500, 42);
            assert!(g.validate().is_ok(), "{}: {:?}", fam.name(), g.validate());
            assert!(g.n_edges() > 0, "{} produced empty graph", fam.name());
            assert!(g.nr >= 250 && g.nc >= 250, "{} too small: {:?}", fam.name(), g);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        for fam in Family::ALL {
            assert_eq!(fam.generate(300, 7), fam.generate(300, 7), "{}", fam.name());
        }
    }

    #[test]
    fn seeds_differ() {
        let a = Family::Kron.generate(400, 1);
        let b = Family::Kron.generate(400, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn name_roundtrip() {
        for fam in Family::ALL {
            assert_eq!(Family::from_name(fam.name()), Some(fam));
        }
        assert_eq!(Family::from_name("nope"), None);
    }
}
