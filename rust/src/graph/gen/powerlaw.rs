//! Power-law generators: Chung–Lu (social networks), preferential
//! attachment (amazon co-purchase), and a locality-biased web graph
//! (wb-edu / wikipedia stand-ins). These families dominate the paper's
//! Hardest20 sets and are where PFP's behaviour degrades most under RCP
//! permutation.

use crate::graph::builder::EdgeList;
use crate::graph::csr::BipartiteCsr;
use crate::util::rng::Xoshiro256;

/// Chung–Lu: expected degree of vertex i ∝ (i+1)^(-1/(gamma-1)); edges are
/// sampled by picking endpoints proportionally to weight via inverse-CDF on
/// the (closed-form) cumulative weights.
pub fn chung_lu(n: usize, avg_deg: f64, gamma: f64, seed: u64) -> BipartiteCsr {
    assert!(gamma > 2.0, "need finite mean");
    let mut rng = Xoshiro256::new(seed);
    let beta = 1.0 / (gamma - 1.0);
    // weights w_i = (i+1)^-beta, cumulative sums for inverse-CDF sampling
    let mut cum = Vec::with_capacity(n + 1);
    cum.push(0.0f64);
    let mut total = 0.0;
    for i in 0..n {
        total += ((i + 1) as f64).powf(-beta);
        cum.push(total);
    }
    let m = (n as f64 * avg_deg / 2.0) as usize;
    let sample = |rng: &mut Xoshiro256| -> usize {
        let t = rng.next_f64() * total;
        // binary search for the containing interval
        match cum.binary_search_by(|x| x.partial_cmp(&t).unwrap()) {
            Ok(i) => i.min(n - 1),
            Err(i) => (i - 1).min(n - 1),
        }
    };
    let mut el = EdgeList::with_capacity(n, n, 2 * m + n);
    for v in 0..n {
        if rng.gen_bool(0.3) {
            el.add(v, v);
        }
    }
    for _ in 0..m {
        let u = sample(&mut rng);
        let v = sample(&mut rng);
        el.add(u, v);
        el.add(v, u);
    }
    el.build()
}

/// Preferential attachment with `k` out-edges per vertex, implemented with
/// the edge-endpoint-array trick (sampling a uniform endpoint of an
/// existing edge is proportional-to-degree). Low-degree, long-tailed —
/// amazon-0505-like.
pub fn pref_attach(n: usize, k: usize, seed: u64) -> BipartiteCsr {
    assert!(k >= 1);
    let mut rng = Xoshiro256::new(seed);
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * k);
    let mut el = EdgeList::with_capacity(n, n, n * (k + 1));
    for v in 0..n {
        if rng.gen_bool(0.5) {
            el.add(v, v);
        }
        let targets = k.min(v);
        for _ in 0..targets {
            let t = if endpoints.is_empty() || rng.gen_bool(0.2) {
                rng.gen_range(v) as u32 // uniform escape keeps graph connected-ish
            } else {
                endpoints[rng.gen_range(endpoints.len())]
            };
            el.add(v, t as usize);
            endpoints.push(v as u32);
            endpoints.push(t);
        }
    }
    el.build()
}

/// Web-like: power-law out-degree, and targets biased toward nearby ids
/// (host locality) with occasional global hops — produces the asymmetric,
/// rectangular-ish structure of crawl matrices.
pub fn web_graph(n: usize, avg_deg: f64, seed: u64) -> BipartiteCsr {
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::with_capacity(n, n, (n as f64 * (avg_deg + 1.0)) as usize);
    for v in 0..n {
        if rng.gen_bool(0.5) {
            el.add(v, v);
        }
        // out-degree: power-law sample in [0, 4*avg)
        let cap = (4.0 * avg_deg) as usize + 1;
        let deg = rng.powerlaw(cap, 2.2);
        for _ in 0..deg {
            let t = if rng.gen_bool(0.8) {
                // local: within a window of ±n/64 (same "host")
                let w = (n / 64).max(4);
                let lo = v.saturating_sub(w / 2);
                let hi = (v + w / 2).min(n - 1);
                lo + rng.gen_range(hi - lo + 1)
            } else {
                rng.gen_range(n)
            };
            el.add(v, t);
        }
    }
    el.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chung_lu_valid_and_skewed() {
        let g = chung_lu(2000, 6.0, 2.3, 9);
        assert!(g.validate().is_ok());
        let avg = g.avg_col_degree();
        assert!(g.max_col_degree() as f64 > 3.0 * avg);
    }

    #[test]
    fn chung_lu_avg_degree_ballpark() {
        let g = chung_lu(4000, 8.0, 2.5, 31);
        let avg = g.avg_col_degree() - 0.3; // minus expected diagonal
        // dedup removes some multi-edges; allow a generous band
        assert!((3.0..9.5).contains(&avg), "avg {avg}");
    }

    #[test]
    fn pref_attach_low_degree_tail() {
        let g = pref_attach(3000, 3, 5);
        assert!(g.validate().is_ok());
        let avg = g.avg_col_degree();
        assert!(avg < 9.0, "amazon-like graphs are sparse, got {avg}");
        assert!(g.max_col_degree() > 3 * 3, "popular targets accumulate column degree");
    }

    #[test]
    fn web_graph_asymmetric() {
        let g = web_graph(2000, 6.0, 77);
        assert!(g.validate().is_ok());
        // web matrices are not symmetric
        let asym = g
            .edges()
            .iter()
            .filter(|&&(r, c)| r != c && !g.has_edge(c as usize, r as usize))
            .count();
        assert!(asym > 0, "expected asymmetric structure");
    }

    #[test]
    fn all_deterministic() {
        assert_eq!(chung_lu(500, 4.0, 2.4, 3), chung_lu(500, 4.0, 2.4, 3));
        assert_eq!(pref_attach(500, 2, 3), pref_attach(500, 2, 3));
        assert_eq!(web_graph(500, 4.0, 3), web_graph(500, 4.0, 3));
    }
}
