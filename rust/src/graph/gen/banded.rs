//! Banded matrix with irregular fill — Hamrle3 stand-in (circuit-simulation
//! matrix: narrow band, patchy density). Notable in the paper as the
//! instance where sequential PFP is near-instant (0.04 s) while BFS-heavy
//! methods pay many iterations (Fig. 2a) — a worst case for APsB/APFB on
//! original ordering, and much harder for everyone after RCP.

use crate::graph::builder::EdgeList;
use crate::graph::csr::BipartiteCsr;
use crate::util::rng::Xoshiro256;

/// `band`: half-bandwidth; `fill`: probability a band slot is a nonzero.
pub fn banded(n: usize, band: usize, fill: f64, seed: u64) -> BipartiteCsr {
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::with_capacity(n, n, (n as f64 * band as f64 * fill) as usize + n);
    for i in 0..n {
        el.add(i, i);
        // irregular fill: density waves along the band (patchy blocks like
        // circuit matrices) — modulate fill by a slow sawtooth
        let local = fill * (0.5 + ((i / 64) % 3) as f64 * 0.35);
        let lo = i.saturating_sub(band);
        let hi = (i + band).min(n - 1);
        for j in lo..=hi {
            if j != i && rng.gen_bool(local) {
                el.add(i, j);
            }
        }
    }
    el.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banded_stays_in_band() {
        let band = 10;
        let g = banded(500, band, 0.4, 3);
        assert!(g.validate().is_ok());
        for (r, c) in g.edges() {
            let d = (r as i64 - c as i64).unsigned_abs() as usize;
            assert!(d <= band, "edge ({r},{c}) outside band");
        }
    }

    #[test]
    fn diagonal_full() {
        let g = banded(200, 5, 0.2, 1);
        for i in 0..200 {
            assert!(g.has_edge(i, i));
        }
    }

    #[test]
    fn fill_controls_density() {
        let sparse = banded(400, 8, 0.1, 2);
        let dense = banded(400, 8, 0.8, 2);
        assert!(dense.n_edges() > 2 * sparse.n_edges());
    }

    #[test]
    fn deterministic() {
        assert_eq!(banded(300, 6, 0.3, 9), banded(300, 6, 0.3, 9));
    }
}
