//! Random geometric graph (rgg_n_2_24_s0 stand-in): n points uniform in the
//! unit square, edge when distance < r. Grid-bucketed neighbor search keeps
//! generation O(n) for the constant-expected-degree radii we use.

use crate::graph::builder::EdgeList;
use crate::graph::csr::BipartiteCsr;
use crate::util::rng::Xoshiro256;

/// `avg_deg` calibrates the radius: E[deg] = n·π·r² ⇒ r = sqrt(avg/(πn)).
pub fn rgg(n: usize, avg_deg: f64, seed: u64) -> BipartiteCsr {
    let mut rng = Xoshiro256::new(seed);
    let r = (avg_deg / (std::f64::consts::PI * n as f64)).sqrt();
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();

    // bucket grid with cell size >= r so neighbors are within 3x3 cells
    let cells = ((1.0 / r) as usize).clamp(1, 4096);
    let cell_of = |p: (f64, f64)| -> (usize, usize) {
        (
            ((p.0 * cells as f64) as usize).min(cells - 1),
            ((p.1 * cells as f64) as usize).min(cells - 1),
        )
    };
    let mut grid: Vec<Vec<u32>> = vec![Vec::new(); cells * cells];
    for (i, &p) in pts.iter().enumerate() {
        let (cx, cy) = cell_of(p);
        grid[cx * cells + cy].push(i as u32);
    }

    let mut el = EdgeList::with_capacity(n, n, (n as f64 * (avg_deg + 1.0)) as usize);
    let r2 = r * r;
    for i in 0..n {
        let (cx, cy) = cell_of(pts[i]);
        let x0 = cx.saturating_sub(1);
        let y0 = cy.saturating_sub(1);
        for x in x0..=(cx + 1).min(cells - 1) {
            for y in y0..=(cy + 1).min(cells - 1) {
                for &j in &grid[x * cells + y] {
                    let j = j as usize;
                    if j <= i {
                        continue;
                    }
                    let dx = pts[i].0 - pts[j].0;
                    let dy = pts[i].1 - pts[j].1;
                    if dx * dx + dy * dy < r2 {
                        el.add(i, j);
                        el.add(j, i);
                    }
                }
            }
        }
    }
    el.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rgg_degree_near_target() {
        let g = rgg(2000, 4.0, 17);
        assert!(g.validate().is_ok());
        // average degree should be in the ballpark of 4
        let avg = g.avg_col_degree();
        assert!((2.0..7.0).contains(&avg), "avg degree {avg}");
    }

    #[test]
    fn rgg_symmetric() {
        let g = rgg(500, 3.0, 23);
        for (r, c) in g.edges() {
            assert!(g.has_edge(c as usize, r as usize));
        }
    }

    #[test]
    fn rgg_deterministic() {
        assert_eq!(rgg(300, 3.0, 5), rgg(300, 3.0, 5));
    }

    #[test]
    fn rgg_tiny() {
        let g = rgg(3, 1.0, 1);
        assert!(g.validate().is_ok());
        assert_eq!(g.nr, 3);
    }
}
