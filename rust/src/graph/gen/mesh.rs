//! Mesh-like generators: road networks, triangulations, long traces.
//! These model the paper's roadNet-CA, delaunay_nXX, hugetrace /
//! hugebubbles instances: near-planar, bounded degree, huge diameter —
//! the regime where BFS-based algorithms need many levels and where APFB
//! vs APsB behaviour diverges (paper Fig. 2b).

use crate::graph::builder::EdgeList;
use crate::graph::csr::BipartiteCsr;
use crate::util::rng::Xoshiro256;

/// Adjacency pattern (plus diagonal) of an s×s grid graph with random edge
/// deletions — a road-network stand-in. `n` is the target vertex count per
/// side; the realized size is s² for s = ceil(sqrt(n)).
pub fn grid_road(n: usize, del_p: f64, seed: u64) -> BipartiteCsr {
    let s = (n as f64).sqrt().ceil() as usize;
    let nv = s * s;
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::with_capacity(nv, nv, nv * 5);
    let idx = |x: usize, y: usize| x * s + y;
    for x in 0..s {
        for y in 0..s {
            let v = idx(x, y);
            // no diagonal: adjacency matrices of road networks have none,
            // which keeps the cheap-matching init from trivially completing
            if x + 1 < s && !rng.gen_bool(del_p) {
                let u = idx(x + 1, y);
                el.add(v, u);
                el.add(u, v);
            }
            if y + 1 < s && !rng.gen_bool(del_p) {
                let u = idx(x, y + 1);
                el.add(v, u);
                el.add(u, v);
            }
        }
    }
    el.build()
}

/// Triangulation-like mesh: grid plus one random diagonal per cell
/// (delaunay_nXX stand-in — degree ~6, planar).
pub fn delaunay_like(n: usize, seed: u64) -> BipartiteCsr {
    let s = (n as f64).sqrt().ceil() as usize;
    let nv = s * s;
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::with_capacity(nv, nv, nv * 7);
    let idx = |x: usize, y: usize| x * s + y;
    for x in 0..s {
        for y in 0..s {
            let v = idx(x, y);
            if x + 1 < s {
                el.add(v, idx(x + 1, y));
                el.add(idx(x + 1, y), v);
            }
            if y + 1 < s {
                el.add(v, idx(x, y + 1));
                el.add(idx(x, y + 1), v);
            }
            if x + 1 < s && y + 1 < s {
                // one diagonal per cell, random orientation
                let (a, b) = if rng.gen_bool(0.5) {
                    (idx(x, y), idx(x + 1, y + 1))
                } else {
                    (idx(x + 1, y), idx(x, y + 1))
                };
                el.add(a, b);
                el.add(b, a);
            }
        }
    }
    el.build()
}

/// Long thin perforated mesh (aspect ratio 16:1) with circular holes —
/// hugetrace/hugebubbles stand-in. Enormous diameter relative to size.
pub fn hugetrace(n: usize, hole_p: f64, seed: u64) -> BipartiteCsr {
    let w = ((n as f64) / 16.0).sqrt().ceil() as usize;
    let h = w * 16;
    let nv = w.max(1) * h.max(1);
    let mut rng = Xoshiro256::new(seed);
    // punch holes: a vertex keeps its edges unless inside a hole
    let mut holed = vec![false; nv];
    let nholes = ((nv as f64) * hole_p / 9.0) as usize;
    for _ in 0..nholes {
        let cx = rng.gen_range(w.max(1));
        let cy = rng.gen_range(h.max(1));
        for dx in 0..3usize {
            for dy in 0..3usize {
                let (x, y) = (cx + dx, cy + dy);
                if x < w && y < h {
                    holed[x * h + y] = true;
                }
            }
        }
    }
    let mut el = EdgeList::with_capacity(nv, nv, nv * 5);
    let idx = |x: usize, y: usize| x * h + y;
    for x in 0..w {
        for y in 0..h {
            let v = idx(x, y);
            if holed[v] {
                // keep the vertex isolated (hole interior)
                continue;
            }
            if x + 1 < w && !holed[idx(x + 1, y)] {
                el.add(v, idx(x + 1, y));
                el.add(idx(x + 1, y), v);
            }
            if y + 1 < h && !holed[idx(x, y + 1)] {
                el.add(v, idx(x, y + 1));
                el.add(idx(x, y + 1), v);
            }
        }
    }
    el.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_road_structure() {
        let g = grid_road(400, 0.1, 3);
        assert_eq!(g.nr, 400); // 20x20
        assert!(g.validate().is_ok());
        // bounded degree: at most 4 grid neighbors
        assert!(g.max_col_degree() <= 4);
    }

    #[test]
    fn grid_road_deletion_rate() {
        let g_none = grid_road(900, 0.0, 5);
        let g_half = grid_road(900, 0.5, 5);
        assert!(g_half.n_edges() < g_none.n_edges());
    }

    #[test]
    fn delaunay_has_diagonals() {
        let g = delaunay_like(100, 11);
        assert!(g.validate().is_ok());
        assert!(g.max_col_degree() <= 8); // 4 grid + up to 4 cell diagonals
        // more edges than the plain grid with same s
        let grid = grid_road(100, 0.0, 11);
        assert!(g.n_edges() > grid.n_edges());
    }

    #[test]
    fn hugetrace_is_long() {
        let g = hugetrace(1024, 0.05, 13);
        assert!(g.validate().is_ok());
        assert!(g.nr >= 1024);
        assert!(g.max_col_degree() <= 4);
    }

    #[test]
    fn symmetric_patterns() {
        // all three generators emit symmetric matrices
        for g in [grid_road(144, 0.2, 1), delaunay_like(144, 1), hugetrace(256, 0.1, 1)] {
            for (r, c) in g.edges() {
                assert!(g.has_edge(c as usize, r as usize), "asymmetric edge ({r},{c})");
            }
        }
    }
}
