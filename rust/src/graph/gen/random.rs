//! Uniform sparse random bipartite graphs (Erdős–Rényi G(nr, nc, m)) —
//! the control family: no structure, so algorithm behaviour isolates the
//! effect of degree alone. Also supports rectangular instances (nr != nc),
//! which exercise the deficient-matching code paths (|M| < min(nr, nc)).

use crate::graph::builder::EdgeList;
use crate::graph::csr::BipartiteCsr;
use crate::util::rng::Xoshiro256;

/// `avg_deg` is the expected column degree; edges sampled uniformly with
/// replacement then dedup'd.
pub fn uniform_random(nr: usize, nc: usize, avg_deg: f64, seed: u64) -> BipartiteCsr {
    let mut rng = Xoshiro256::new(seed);
    let m = (nc as f64 * avg_deg) as usize;
    let mut el = EdgeList::with_capacity(nr, nc, m);
    for _ in 0..m {
        el.add(rng.gen_range(nr), rng.gen_range(nc));
    }
    el.build()
}

/// A graph with a known *perfect* matching planted: random permutation
/// edges plus noise. Used by tests that need a certified optimum.
pub fn with_perfect_matching(n: usize, extra_deg: f64, seed: u64) -> BipartiteCsr {
    let mut rng = Xoshiro256::new(seed);
    let perm = rng.permutation(n);
    let extra = (n as f64 * extra_deg) as usize;
    let mut el = EdgeList::with_capacity(n, n, n + extra);
    for (c, &r) in perm.iter().enumerate() {
        el.add(r as usize, c);
    }
    for _ in 0..extra {
        el.add(rng.gen_range(n), rng.gen_range(n));
    }
    el.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_basic() {
        let g = uniform_random(1000, 1000, 4.0, 3);
        assert!(g.validate().is_ok());
        let avg = g.avg_col_degree();
        assert!((3.0..4.5).contains(&avg), "avg {avg}");
    }

    #[test]
    fn rectangular_supported() {
        let g = uniform_random(100, 300, 3.0, 5);
        assert_eq!(g.nr, 100);
        assert_eq!(g.nc, 300);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn planted_perfect_matching_has_n_disjoint_edges() {
        let n = 200;
        let g = with_perfect_matching(n, 2.0, 7);
        assert!(g.validate().is_ok());
        // the planted permutation guarantees a perfect matching exists;
        // verify via Hall-style check of the planted edges themselves:
        // every column has at least one neighbor, and the planted edges are
        // a permutation by construction. A full optimality check lives in
        // matching::tests.
        for c in 0..n {
            assert!(g.col_degree(c) >= 1);
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(uniform_random(100, 100, 3.0, 1), uniform_random(100, 100, 3.0, 1));
        assert_eq!(with_perfect_matching(100, 1.0, 2), with_perfect_matching(100, 1.0, 2));
    }
}
