//! Bipartite graph in compressed-sparse-row form, column-major primary —
//! the paper's `cxadj`/`cadj` arrays (the BFS kernels sweep *column*
//! vertices). The row-side adjacency (`rxadj`/`radj`) is kept too: the
//! sequential/multicore baselines (PFP, DFS, HK's DFS phase) walk both
//! sides.
//!
//! In the sparse-matrix reading of the paper, columns are one vertex class
//! and rows the other; an edge (r, c) is a structural nonzero A[r][c].

use std::fmt;

/// Immutable bipartite graph. Invariants (checked by [`BipartiteCsr::validate`]):
/// * `cxadj.len() == nc + 1`, `cxadj[0] == 0`, non-decreasing,
///   `cxadj[nc] == cadj.len()`
/// * every entry of `cadj` is a valid row id `< nr`
/// * neighbor lists are sorted and duplicate-free
/// * row-side arrays are the exact transpose of the column-side ones.
#[derive(Clone, PartialEq, Eq)]
pub struct BipartiteCsr {
    /// number of row vertices
    pub nr: usize,
    /// number of column vertices
    pub nc: usize,
    /// column pointers, len nc+1
    pub cxadj: Vec<u32>,
    /// row ids per column, len = #edges
    pub cadj: Vec<u32>,
    /// row pointers, len nr+1 (transpose)
    pub rxadj: Vec<u32>,
    /// column ids per row, len = #edges
    pub radj: Vec<u32>,
}

impl fmt::Debug for BipartiteCsr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "BipartiteCsr {{ nr: {}, nc: {}, edges: {} }}",
            self.nr,
            self.nc,
            self.n_edges()
        )
    }
}

impl BipartiteCsr {
    /// Build from column-side CSR arrays; computes the row-side transpose.
    /// Neighbor lists are sorted; duplicates must already be removed (use
    /// [`crate::graph::builder::EdgeList`] for raw input).
    pub fn from_col_csr(nr: usize, nc: usize, cxadj: Vec<u32>, mut cadj: Vec<u32>) -> Self {
        assert_eq!(cxadj.len(), nc + 1, "cxadj must have nc+1 entries");
        assert_eq!(*cxadj.last().unwrap() as usize, cadj.len());
        // sort each neighbor list
        for c in 0..nc {
            let (lo, hi) = (cxadj[c] as usize, cxadj[c + 1] as usize);
            cadj[lo..hi].sort_unstable();
        }
        let (rxadj, radj) = transpose(nr, &cxadj, &cadj);
        let g = Self { nr, nc, cxadj, cadj, rxadj, radj };
        debug_assert!(g.validate().is_ok(), "{:?}", g.validate());
        g
    }

    pub fn n_edges(&self) -> usize {
        self.cadj.len()
    }

    /// Neighbor rows of column `c`.
    #[inline]
    pub fn col_neighbors(&self, c: usize) -> &[u32] {
        &self.cadj[self.cxadj[c] as usize..self.cxadj[c + 1] as usize]
    }

    /// Neighbor columns of row `r`.
    #[inline]
    pub fn row_neighbors(&self, r: usize) -> &[u32] {
        &self.radj[self.rxadj[r] as usize..self.rxadj[r + 1] as usize]
    }

    #[inline]
    pub fn col_degree(&self, c: usize) -> usize {
        (self.cxadj[c + 1] - self.cxadj[c]) as usize
    }

    #[inline]
    pub fn row_degree(&self, r: usize) -> usize {
        (self.rxadj[r + 1] - self.rxadj[r]) as usize
    }

    pub fn max_col_degree(&self) -> usize {
        (0..self.nc).map(|c| self.col_degree(c)).max().unwrap_or(0)
    }

    pub fn max_row_degree(&self) -> usize {
        (0..self.nr).map(|r| self.row_degree(r)).max().unwrap_or(0)
    }

    /// Average column degree (edges / nc).
    pub fn avg_col_degree(&self) -> f64 {
        if self.nc == 0 {
            0.0
        } else {
            self.n_edges() as f64 / self.nc as f64
        }
    }

    pub fn has_edge(&self, r: usize, c: usize) -> bool {
        self.col_neighbors(c).binary_search(&(r as u32)).is_ok()
    }

    /// Full structural validation; returns a description of the first
    /// violated invariant.
    pub fn validate(&self) -> Result<(), String> {
        if self.cxadj.len() != self.nc + 1 {
            return Err(format!("cxadj len {} != nc+1 {}", self.cxadj.len(), self.nc + 1));
        }
        if self.rxadj.len() != self.nr + 1 {
            return Err(format!("rxadj len {} != nr+1 {}", self.rxadj.len(), self.nr + 1));
        }
        if self.cxadj[0] != 0 || self.rxadj[0] != 0 {
            return Err("pointer arrays must start at 0".into());
        }
        if self.cxadj.windows(2).any(|w| w[0] > w[1]) {
            return Err("cxadj not non-decreasing".into());
        }
        if self.rxadj.windows(2).any(|w| w[0] > w[1]) {
            return Err("rxadj not non-decreasing".into());
        }
        if *self.cxadj.last().unwrap() as usize != self.cadj.len() {
            return Err("cxadj[nc] != |cadj|".into());
        }
        if *self.rxadj.last().unwrap() as usize != self.radj.len() {
            return Err("rxadj[nr] != |radj|".into());
        }
        if self.cadj.len() != self.radj.len() {
            return Err("edge count mismatch between sides".into());
        }
        for c in 0..self.nc {
            let nbrs = self.col_neighbors(c);
            for w in nbrs.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("col {c} neighbors not strictly sorted"));
                }
            }
            if let Some(&r) = nbrs.last() {
                if r as usize >= self.nr {
                    return Err(format!("col {c} references row {r} >= nr {}", self.nr));
                }
            }
        }
        for r in 0..self.nr {
            let nbrs = self.row_neighbors(r);
            for w in nbrs.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} neighbors not strictly sorted"));
                }
            }
            if let Some(&c) = nbrs.last() {
                if c as usize >= self.nc {
                    return Err(format!("row {r} references col {c} >= nc {}", self.nc));
                }
            }
        }
        // transpose consistency
        let (rx2, ra2) = transpose(self.nr, &self.cxadj, &self.cadj);
        if rx2 != self.rxadj || ra2 != self.radj {
            return Err("row-side arrays are not the transpose of column-side".into());
        }
        Ok(())
    }

    /// Edge list (r, c), column-major order.
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.n_edges());
        for c in 0..self.nc {
            for &r in self.col_neighbors(c) {
                out.push((r, c as u32));
            }
        }
        out
    }

    /// Swap the two vertex classes (transpose of the matrix).
    pub fn transposed(&self) -> BipartiteCsr {
        BipartiteCsr {
            nr: self.nc,
            nc: self.nr,
            cxadj: self.rxadj.clone(),
            cadj: self.radj.clone(),
            rxadj: self.cxadj.clone(),
            radj: self.cadj.clone(),
        }
    }
}

/// Transpose column-side CSR to row-side CSR (counting sort; output
/// neighbor lists come out sorted because columns are visited in order).
fn transpose(nr: usize, cxadj: &[u32], cadj: &[u32]) -> (Vec<u32>, Vec<u32>) {
    let nc = cxadj.len() - 1;
    let mut rxadj = vec![0u32; nr + 1];
    for &r in cadj {
        rxadj[r as usize + 1] += 1;
    }
    for i in 0..nr {
        rxadj[i + 1] += rxadj[i];
    }
    let mut radj = vec![0u32; cadj.len()];
    let mut fill = rxadj.clone();
    for c in 0..nc {
        for &r in &cadj[cxadj[c] as usize..cxadj[c + 1] as usize] {
            let slot = fill[r as usize] as usize;
            radj[slot] = c as u32;
            fill[r as usize] += 1;
        }
    }
    (rxadj, radj)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> BipartiteCsr {
        // c0-r0, c0-r1, c1-r1  (the paper's Fig. 1 minus one edge)
        BipartiteCsr::from_col_csr(2, 2, vec![0, 2, 3], vec![0, 1, 1])
    }

    #[test]
    fn build_and_validate() {
        let g = path3();
        assert_eq!(g.n_edges(), 3);
        assert!(g.validate().is_ok());
        assert_eq!(g.col_neighbors(0), &[0, 1]);
        assert_eq!(g.col_neighbors(1), &[1]);
        assert_eq!(g.row_neighbors(0), &[0]);
        assert_eq!(g.row_neighbors(1), &[0, 1]);
    }

    #[test]
    fn degrees() {
        let g = path3();
        assert_eq!(g.col_degree(0), 2);
        assert_eq!(g.col_degree(1), 1);
        assert_eq!(g.row_degree(1), 2);
        assert_eq!(g.max_col_degree(), 2);
        assert_eq!(g.max_row_degree(), 2);
        assert!((g.avg_col_degree() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn has_edge() {
        let g = path3();
        assert!(g.has_edge(0, 0));
        assert!(g.has_edge(1, 0));
        assert!(g.has_edge(1, 1));
        assert!(!g.has_edge(0, 1));
    }

    #[test]
    fn edges_roundtrip() {
        let g = path3();
        assert_eq!(g.edges(), vec![(0, 0), (1, 0), (1, 1)]);
    }

    #[test]
    fn transpose_involution() {
        let g = path3();
        let t = g.transposed();
        assert!(t.validate().is_ok());
        assert_eq!(t.transposed(), g);
        assert_eq!(t.nr, g.nc);
        assert!(t.has_edge(0, 0) && t.has_edge(0, 1) && t.has_edge(1, 1));
    }

    #[test]
    fn unsorted_input_gets_sorted() {
        let g = BipartiteCsr::from_col_csr(3, 1, vec![0, 3], vec![2, 0, 1]);
        assert_eq!(g.col_neighbors(0), &[0, 1, 2]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn empty_graph() {
        let g = BipartiteCsr::from_col_csr(0, 0, vec![0], vec![]);
        assert!(g.validate().is_ok());
        assert_eq!(g.n_edges(), 0);
    }

    #[test]
    fn isolated_vertices() {
        let g = BipartiteCsr::from_col_csr(3, 3, vec![0, 0, 1, 1], vec![2]);
        assert!(g.validate().is_ok());
        assert_eq!(g.col_degree(0), 0);
        assert_eq!(g.col_degree(1), 1);
        assert_eq!(g.row_degree(0), 0);
        assert_eq!(g.row_degree(2), 1);
    }
}
