//! Random row/column permutation — the paper's RCP instance sets.
//!
//! "We also permuted the matrices randomly by rows and columns and included
//! them as a second set (labeled RCP). These permutations usually render
//! the problems harder for the augmenting-path-based algorithms." (§4)
//! Permutation preserves the matching *cardinality* exactly (it is an
//! isomorphism of the bipartite graph), which the tests assert.

use super::builder::EdgeList;
use super::csr::BipartiteCsr;
use crate::util::rng::Xoshiro256;

/// Apply explicit permutations: new_row = rperm[old_row],
/// new_col = cperm[old_col].
pub fn permute(g: &BipartiteCsr, rperm: &[u32], cperm: &[u32]) -> BipartiteCsr {
    assert_eq!(rperm.len(), g.nr);
    assert_eq!(cperm.len(), g.nc);
    debug_assert!(is_permutation(rperm) && is_permutation(cperm));
    let mut el = EdgeList::with_capacity(g.nr, g.nc, g.n_edges());
    for c in 0..g.nc {
        for &r in g.col_neighbors(c) {
            el.add(rperm[r as usize] as usize, cperm[c] as usize);
        }
    }
    el.build()
}

/// Seeded random row+column permutation (the RCP transform).
pub fn random_permute(g: &BipartiteCsr, seed: u64) -> BipartiteCsr {
    let mut rng = Xoshiro256::new(seed);
    let rperm = rng.permutation(g.nr);
    let cperm = rng.permutation(g.nc);
    permute(g, &rperm, &cperm)
}

fn is_permutation(p: &[u32]) -> bool {
    let mut seen = vec![false; p.len()];
    for &v in p {
        if v as usize >= p.len() || seen[v as usize] {
            return false;
        }
        seen[v as usize] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_edges;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn identity_permutation_is_noop() {
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2), (0, 2)]);
        let id_r: Vec<u32> = (0..3).collect();
        let id_c: Vec<u32> = (0..3).collect();
        assert_eq!(permute(&g, &id_r, &id_c), g);
    }

    #[test]
    fn explicit_permutation_moves_edges() {
        let g = from_edges(2, 2, &[(0, 0), (1, 1)]);
        let p = permute(&g, &[1, 0], &[0, 1]);
        assert!(p.has_edge(1, 0) && p.has_edge(0, 1));
        assert!(!p.has_edge(0, 0));
    }

    #[test]
    fn random_permute_preserves_counts_and_degrees_multiset() {
        forall(Config::cases(25), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            let p = random_permute(&g, rng.next_u64());
            if p.n_edges() != g.n_edges() {
                return Err("edge count changed".into());
            }
            p.validate().map_err(|e| format!("invalid after permute: {e}"))?;
            let mut dg: Vec<usize> = (0..nc).map(|c| g.col_degree(c)).collect();
            let mut dp: Vec<usize> = (0..nc).map(|c| p.col_degree(c)).collect();
            dg.sort_unstable();
            dp.sort_unstable();
            if dg != dp {
                return Err("column degree multiset changed".into());
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_by_seed() {
        let g = from_edges(5, 5, &[(0, 1), (2, 3), (4, 0), (1, 1), (3, 2)]);
        assert_eq!(random_permute(&g, 99), random_permute(&g, 99));
        assert_ne!(random_permute(&g, 99), random_permute(&g, 100));
    }
}
