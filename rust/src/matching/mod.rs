//! Matching representation and certification.
//!
//! The paper's convention is kept verbatim: `rmatch[r] = c` and
//! `cmatch[c] = r` when row r is matched to column c; `-1` marks an
//! unmatched vertex. (The GPU kernels additionally use `rmatch[r] = -2` as
//! the "augmenting-path endpoint" sentinel *during* a phase; a final
//! [`Matching`] must contain no `-2`.)

pub mod algo;
pub mod init;
pub mod koenig;

use crate::graph::csr::BipartiteCsr;

pub const UNMATCHED: i32 = -1;

/// A (partial) matching over a bipartite graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Matching {
    pub rmatch: Vec<i32>,
    pub cmatch: Vec<i32>,
}

impl Matching {
    /// The empty matching for a graph of `nr` rows and `nc` columns.
    pub fn empty(nr: usize, nc: usize) -> Self {
        Self { rmatch: vec![UNMATCHED; nr], cmatch: vec![UNMATCHED; nc] }
    }

    /// Build from a `cmatch` vector (rmatch derived); panics on
    /// inconsistency.
    pub fn from_cmatch(nr: usize, cmatch: Vec<i32>) -> Self {
        let mut rmatch = vec![UNMATCHED; nr];
        for (c, &r) in cmatch.iter().enumerate() {
            if r >= 0 {
                assert!(
                    rmatch[r as usize] == UNMATCHED,
                    "row {r} matched to two columns"
                );
                rmatch[r as usize] = c as i32;
            }
        }
        Self { rmatch, cmatch }
    }

    pub fn nr(&self) -> usize {
        self.rmatch.len()
    }

    pub fn nc(&self) -> usize {
        self.cmatch.len()
    }

    /// Number of matched edges.
    pub fn cardinality(&self) -> usize {
        self.cmatch.iter().filter(|&&r| r >= 0).count()
    }

    #[inline]
    pub fn is_col_matched(&self, c: usize) -> bool {
        self.cmatch[c] >= 0
    }

    #[inline]
    pub fn is_row_matched(&self, r: usize) -> bool {
        self.rmatch[r] >= 0
    }

    /// Match row r with column c (both must be free).
    #[inline]
    pub fn join(&mut self, r: usize, c: usize) {
        debug_assert!(self.rmatch[r] == UNMATCHED && self.cmatch[c] == UNMATCHED);
        self.rmatch[r] = c as i32;
        self.cmatch[c] = r as i32;
    }

    /// Structural validity: mutual consistency and edge existence.
    pub fn validate(&self, g: &BipartiteCsr) -> Result<(), String> {
        if self.rmatch.len() != g.nr || self.cmatch.len() != g.nc {
            return Err(format!(
                "size mismatch: matching {}x{}, graph {}x{}",
                self.rmatch.len(),
                self.cmatch.len(),
                g.nr,
                g.nc
            ));
        }
        for (c, &r) in self.cmatch.iter().enumerate() {
            if r < UNMATCHED {
                return Err(format!("cmatch[{c}] = {r} is a leftover sentinel"));
            }
            if r >= 0 {
                let r = r as usize;
                if r >= g.nr {
                    return Err(format!("cmatch[{c}] = {r} out of range"));
                }
                if self.rmatch[r] != c as i32 {
                    return Err(format!(
                        "cmatch[{c}] = {r} but rmatch[{r}] = {}",
                        self.rmatch[r]
                    ));
                }
                if !g.has_edge(r, c) {
                    return Err(format!("matched pair ({r},{c}) is not an edge"));
                }
            }
        }
        for (r, &c) in self.rmatch.iter().enumerate() {
            if c < UNMATCHED {
                return Err(format!("rmatch[{r}] = {c} is a leftover sentinel"));
            }
            if c >= 0 {
                let c = c as usize;
                if c >= g.nc {
                    return Err(format!("rmatch[{r}] = {c} out of range"));
                }
                if self.cmatch[c] != r as i32 {
                    return Err(format!(
                        "rmatch[{r}] = {c} but cmatch[{c}] = {}",
                        self.cmatch[c]
                    ));
                }
            }
        }
        Ok(())
    }

    /// Maximality certificate (Berge): the matching is maximum iff no
    /// augmenting path exists from any unmatched column. One combined
    /// alternating BFS decides this in O(n + τ).
    pub fn is_maximum(&self, g: &BipartiteCsr) -> bool {
        !self.has_augmenting_path(g)
    }

    /// Combined alternating BFS from all unmatched columns; true if an
    /// unmatched row is reachable.
    pub fn has_augmenting_path(&self, g: &BipartiteCsr) -> bool {
        let mut visited_col = vec![false; g.nc];
        let mut frontier: Vec<u32> = (0..g.nc)
            .filter(|&c| self.cmatch[c] == UNMATCHED && g.col_degree(c) > 0)
            .map(|c| c as u32)
            .collect();
        for &c in &frontier {
            visited_col[c as usize] = true;
        }
        let mut next = Vec::new();
        while !frontier.is_empty() {
            for &c in &frontier {
                for &r in g.col_neighbors(c as usize) {
                    let rm = self.rmatch[r as usize];
                    if rm == UNMATCHED {
                        return true; // augmenting path found
                    }
                    let mc = rm as usize;
                    if !visited_col[mc] {
                        visited_col[mc] = true;
                        next.push(mc as u32);
                    }
                }
            }
            std::mem::swap(&mut frontier, &mut next);
            next.clear();
        }
        false
    }

    /// Full certification: valid AND maximum.
    pub fn certify(&self, g: &BipartiteCsr) -> Result<(), String> {
        self.validate(g)?;
        if !self.is_maximum(g) {
            return Err(format!(
                "matching of cardinality {} is not maximum (augmenting path exists)",
                self.cardinality()
            ));
        }
        Ok(())
    }
}

/// The size of a maximum matching computed by a trusted, simple reference
/// (textbook DFS Hungarian algorithm, O(n·τ)) — the oracle the test suite
/// measures every production algorithm against.
pub fn reference_max_cardinality(g: &BipartiteCsr) -> usize {
    let mut m = Matching::empty(g.nr, g.nc);
    let mut visited = vec![u32::MAX; g.nr];
    for c in 0..g.nc {
        dfs_augment(g, c, &mut m, &mut visited, c as u32);
    }
    m.cardinality()
}

fn dfs_augment(
    g: &BipartiteCsr,
    c: usize,
    m: &mut Matching,
    visited: &mut [u32],
    stamp: u32,
) -> bool {
    for &r in g.col_neighbors(c) {
        let r = r as usize;
        if visited[r] == stamp {
            continue;
        }
        visited[r] = stamp;
        if m.rmatch[r] == UNMATCHED || {
            let c2 = m.rmatch[r] as usize;
            dfs_augment(g, c2, m, visited, stamp)
        } {
            m.rmatch[r] = c as i32;
            m.cmatch[c] = r as i32;
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    fn fig1() -> BipartiteCsr {
        // Paper Fig. 1: c1-r1, c1-r4(c?) ... simplified: c0 adj r0,r1,r2;
        // c1 adj r0. Perfect-matchable.
        from_edges(3, 2, &[(0, 0), (1, 0), (2, 0), (0, 1)])
    }

    #[test]
    fn empty_matching_valid() {
        let g = fig1();
        let m = Matching::empty(g.nr, g.nc);
        assert!(m.validate(&g).is_ok());
        assert_eq!(m.cardinality(), 0);
        assert!(!m.is_maximum(&g)); // augmenting path exists
    }

    #[test]
    fn join_and_validate() {
        let g = fig1();
        let mut m = Matching::empty(g.nr, g.nc);
        m.join(0, 1);
        m.join(1, 0);
        assert!(m.validate(&g).is_ok());
        assert_eq!(m.cardinality(), 2);
        assert!(m.is_maximum(&g));
        assert!(m.certify(&g).is_ok());
    }

    #[test]
    fn invalid_non_edge_detected() {
        let g = fig1();
        let mut m = Matching::empty(g.nr, g.nc);
        // (2,1) is not an edge
        m.rmatch[2] = 1;
        m.cmatch[1] = 2;
        assert!(m.validate(&g).is_err());
    }

    #[test]
    fn inconsistent_pointers_detected() {
        let g = fig1();
        let mut m = Matching::empty(g.nr, g.nc);
        m.cmatch[0] = 0; // rmatch[0] still -1
        assert!(m.validate(&g).is_err());
    }

    #[test]
    fn leftover_sentinel_detected() {
        let g = fig1();
        let mut m = Matching::empty(g.nr, g.nc);
        m.rmatch[0] = -2;
        assert!(m.validate(&g).is_err());
    }

    #[test]
    fn suboptimal_not_maximum() {
        let g = fig1();
        let mut m = Matching::empty(g.nr, g.nc);
        m.join(0, 0); // blocks c1's only neighbor; augmenting path exists
        assert!(m.validate(&g).is_ok());
        assert!(!m.is_maximum(&g));
    }

    #[test]
    fn reference_on_known_graphs() {
        assert_eq!(reference_max_cardinality(&fig1()), 2);
        // perfect matching planted
        let g = crate::graph::gen::random::with_perfect_matching(100, 1.5, 3);
        assert_eq!(reference_max_cardinality(&g), 100);
        // star: K_{1,5} from the column side — only 1 edge matchable
        let star = from_edges(5, 1, &[(0, 0), (1, 0), (2, 0), (3, 0), (4, 0)]);
        assert_eq!(reference_max_cardinality(&star), 1);
        // empty graph
        let empty = from_edges(3, 3, &[]);
        assert_eq!(reference_max_cardinality(&empty), 0);
    }

    #[test]
    fn from_cmatch_roundtrip() {
        let m = Matching::from_cmatch(3, vec![1, -1]);
        assert_eq!(m.rmatch, vec![-1, 0, -1]);
        assert_eq!(m.cardinality(), 1);
    }

    #[test]
    #[should_panic(expected = "matched to two")]
    fn from_cmatch_rejects_duplicates() {
        Matching::from_cmatch(2, vec![0, 0]);
    }

    #[test]
    fn prop_reference_cardinality_bounds() {
        forall(Config::cases(30), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            let k = reference_max_cardinality(&g);
            if k > nr.min(nc) {
                return Err(format!("cardinality {k} exceeds min side"));
            }
            // König/Hall sanity: cardinality at least #columns-with-degree /
            // something is hard; check simple lower bound: at least 1 if any
            // edge exists.
            if !edges.is_empty() && k == 0 {
                return Err("nonzero graph but zero matching".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_permutation_invariance() {
        forall(Config::cases(20), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 20);
            let g = from_edges(nr, nc, &edges);
            let p = crate::graph::random_permute(&g, rng.next_u64());
            if reference_max_cardinality(&g) != reference_max_cardinality(&p) {
                return Err("permutation changed max cardinality".into());
            }
            Ok(())
        });
    }
}
