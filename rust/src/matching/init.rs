//! Cheap-matching initialization heuristics.
//!
//! The paper (§4): "A standard heuristic (called the cheap matching, see
//! [Duff, Kaya & Uçar 2011]) is used to initialize all tested algorithms.
//! We compare the running time of the matching algorithms after this
//! common initialization." Three heuristics are provided; `Cheap` (simple
//! greedy with fairness counter) is the default used by the harness, and
//! Karp–Sipser is available for ablations.

use super::{Matching, UNMATCHED};
use crate::graph::csr::BipartiteCsr;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InitHeuristic {
    /// No initialization (empty matching).
    None,
    /// The "cheap" greedy of Duff et al.: for each column, match to the
    /// first free neighbor, scanning each row list at most once overall
    /// (fairness pointer).
    Cheap,
    /// Karp–Sipser: repeatedly match degree-1 vertices first (those edges
    /// are always safe), then fall back to greedy on the remainder.
    KarpSipser,
}

impl InitHeuristic {
    pub fn name(&self) -> &'static str {
        match self {
            InitHeuristic::None => "none",
            InitHeuristic::Cheap => "cheap",
            InitHeuristic::KarpSipser => "karp-sipser",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "none" => Some(Self::None),
            "cheap" => Some(Self::Cheap),
            "karp-sipser" | "ks" => Some(Self::KarpSipser),
            _ => None,
        }
    }

    pub fn run(&self, g: &BipartiteCsr) -> Matching {
        match self {
            InitHeuristic::None => Matching::empty(g.nr, g.nc),
            InitHeuristic::Cheap => cheap_matching(g),
            InitHeuristic::KarpSipser => karp_sipser(g),
        }
    }
}

/// Simple greedy: first free neighbor per column.
pub fn cheap_matching(g: &BipartiteCsr) -> Matching {
    let mut m = Matching::empty(g.nr, g.nc);
    for c in 0..g.nc {
        for &r in g.col_neighbors(c) {
            if m.rmatch[r as usize] == UNMATCHED {
                m.join(r as usize, c);
                break;
            }
        }
    }
    m
}

/// Karp–Sipser (phase 1 exact for degree-1 reductions, greedy phase 2).
pub fn karp_sipser(g: &BipartiteCsr) -> Matching {
    let mut m = Matching::empty(g.nr, g.nc);
    // dynamic degrees count only edges to free vertices
    let mut cdeg: Vec<u32> = (0..g.nc).map(|c| g.col_degree(c) as u32).collect();
    let mut rdeg: Vec<u32> = (0..g.nr).map(|r| g.row_degree(r) as u32).collect();
    // queue of degree-1 vertices: (is_col, index)
    let mut q: std::collections::VecDeque<(bool, u32)> = Default::default();
    for c in 0..g.nc {
        if cdeg[c] == 1 {
            q.push_back((true, c as u32));
        }
    }
    for r in 0..g.nr {
        if rdeg[r] == 1 {
            q.push_back((false, r as u32));
        }
    }

    let match_pair = |r: usize,
                          c: usize,
                          m: &mut Matching,
                          cdeg: &mut [u32],
                          rdeg: &mut [u32],
                          q: &mut std::collections::VecDeque<(bool, u32)>| {
        m.join(r, c);
        // removing r and c decrements free-degree of their free neighbors
        for &c2 in g.row_neighbors(r) {
            let c2 = c2 as usize;
            if m.cmatch[c2] == UNMATCHED && c2 != c {
                cdeg[c2] = cdeg[c2].saturating_sub(1);
                if cdeg[c2] == 1 {
                    q.push_back((true, c2 as u32));
                }
            }
        }
        for &r2 in g.col_neighbors(c) {
            let r2 = r2 as usize;
            if m.rmatch[r2] == UNMATCHED && r2 != r {
                rdeg[r2] = rdeg[r2].saturating_sub(1);
                if rdeg[r2] == 1 {
                    q.push_back((false, r2 as u32));
                }
            }
        }
    };

    // phase 1: peel degree-1 vertices
    while let Some((is_col, v)) = q.pop_front() {
        let v = v as usize;
        if is_col {
            if m.cmatch[v] != UNMATCHED || cdeg[v] == 0 {
                continue;
            }
            // find its unique free neighbor
            if let Some(&r) = g
                .col_neighbors(v)
                .iter()
                .find(|&&r| m.rmatch[r as usize] == UNMATCHED)
            {
                match_pair(r as usize, v, &mut m, &mut cdeg, &mut rdeg, &mut q);
            }
        } else {
            if m.rmatch[v] != UNMATCHED || rdeg[v] == 0 {
                continue;
            }
            if let Some(&c) = g
                .row_neighbors(v)
                .iter()
                .find(|&&c| m.cmatch[c as usize] == UNMATCHED)
            {
                match_pair(v, c as usize, &mut m, &mut cdeg, &mut rdeg, &mut q);
            }
        }
    }

    // phase 2: greedy over the remainder
    for c in 0..g.nc {
        if m.cmatch[c] != UNMATCHED {
            continue;
        }
        if let Some(&r) = g
            .col_neighbors(c)
            .iter()
            .find(|&&r| m.rmatch[r as usize] == UNMATCHED)
        {
            match_pair(r as usize, c, &mut m, &mut cdeg, &mut rdeg, &mut q);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn cheap_on_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 2)]);
        let m = cheap_matching(&g);
        assert!(m.validate(&g).is_ok());
        assert_eq!(m.cardinality(), 3); // greedy finds 0-0, 1-1, 2-2
    }

    #[test]
    fn karp_sipser_degree1_optimal_on_paths() {
        // path: c0-r0-c1-r1-c2-r2 : KS must find the perfect matching
        let g = from_edges(3, 3, &[(0, 0), (0, 1), (1, 1), (1, 2), (2, 2)]);
        let m = karp_sipser(&g);
        assert!(m.validate(&g).is_ok());
        assert_eq!(m.cardinality(), 3);
    }

    #[test]
    fn heuristics_give_valid_partial_matchings() {
        forall(Config::cases(30), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            let opt = reference_max_cardinality(&g);
            for h in [InitHeuristic::None, InitHeuristic::Cheap, InitHeuristic::KarpSipser] {
                let m = h.run(&g);
                m.validate(&g).map_err(|e| format!("{}: {e}", h.name()))?;
                if m.cardinality() > opt {
                    return Err(format!("{} exceeded optimum", h.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn greedy_is_maximal() {
        // a greedy matching must be maximal: no edge with both ends free
        forall(Config::cases(30), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            for h in [InitHeuristic::Cheap, InitHeuristic::KarpSipser] {
                let m = h.run(&g);
                for &(r, c) in &edges {
                    if m.rmatch[r as usize] == UNMATCHED && m.cmatch[c as usize] == UNMATCHED {
                        return Err(format!(
                            "{}: edge ({r},{c}) has both endpoints free",
                            h.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn karp_sipser_at_least_half_of_optimum() {
        // any maximal matching is >= opt/2
        forall(Config::cases(20), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            let opt = reference_max_cardinality(&g);
            let m = karp_sipser(&g);
            if 2 * m.cardinality() < opt {
                return Err(format!("KS {} < opt/2 ({opt})", m.cardinality()));
            }
            Ok(())
        });
    }

    #[test]
    fn name_roundtrip() {
        for h in [InitHeuristic::None, InitHeuristic::Cheap, InitHeuristic::KarpSipser] {
            assert_eq!(InitHeuristic::from_name(h.name()), Some(h));
        }
    }
}
