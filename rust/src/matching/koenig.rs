//! König certificates: from a maximum matching, constructively extract a
//! minimum vertex cover (and its complement, a maximum independent set).
//! By König's theorem |cover| = |M| in bipartite graphs, which gives every
//! caller an *independent* optimality proof — the cover is a witness that
//! no larger matching exists, complementary to the Berge BFS check in
//! [`super::Matching::is_maximum`].

use super::{Matching, UNMATCHED};
use crate::graph::csr::BipartiteCsr;

/// A vertex cover of the bipartite graph split by side.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VertexCover {
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
}

impl VertexCover {
    pub fn size(&self) -> usize {
        self.rows.len() + self.cols.len()
    }

    /// Check that every edge is covered.
    pub fn validate(&self, g: &BipartiteCsr) -> Result<(), String> {
        let mut row_in = vec![false; g.nr];
        let mut col_in = vec![false; g.nc];
        for &r in &self.rows {
            row_in[r as usize] = true;
        }
        for &c in &self.cols {
            col_in[c as usize] = true;
        }
        for c in 0..g.nc {
            for &r in g.col_neighbors(c) {
                if !row_in[r as usize] && !col_in[c] {
                    return Err(format!("edge ({r},{c}) uncovered"));
                }
            }
        }
        Ok(())
    }
}

/// König construction: alternating BFS from the unmatched columns marks
/// reachable vertices Z; the minimum cover is (unreached columns) ∪
/// (reached rows). Requires `m` to be a *maximum* matching — the returned
/// cover having size |M| certifies it; a non-maximum matching yields a
/// cover that fails [`VertexCover::validate`] or exceeds |M|.
pub fn min_vertex_cover(g: &BipartiteCsr, m: &Matching) -> VertexCover {
    let mut col_reached = vec![false; g.nc];
    let mut row_reached = vec![false; g.nr];
    let mut frontier: Vec<u32> = (0..g.nc)
        .filter(|&c| m.cmatch[c] == UNMATCHED)
        .map(|c| {
            col_reached[c] = true;
            c as u32
        })
        .collect();
    let mut next = Vec::new();
    while !frontier.is_empty() {
        for &c in &frontier {
            for &r in g.col_neighbors(c as usize) {
                let r = r as usize;
                if row_reached[r] {
                    continue;
                }
                row_reached[r] = true;
                let rm = m.rmatch[r];
                debug_assert!(rm != UNMATCHED, "maximum matching has no augmenting path");
                if rm >= 0 && !col_reached[rm as usize] {
                    col_reached[rm as usize] = true;
                    next.push(rm as u32);
                }
            }
        }
        std::mem::swap(&mut frontier, &mut next);
        next.clear();
    }
    VertexCover {
        rows: (0..g.nr).filter(|&r| row_reached[r]).map(|r| r as u32).collect(),
        cols: (0..g.nc).filter(|&c| !col_reached[c]).map(|c| c as u32).collect(),
    }
}

/// Full König certification: cover validity + |cover| == |M|.
pub fn certify_with_cover(g: &BipartiteCsr, m: &Matching) -> Result<VertexCover, String> {
    m.validate(g)?;
    let cover = min_vertex_cover(g, m);
    cover.validate(g)?;
    if cover.size() != m.cardinality() {
        return Err(format!(
            "König mismatch: |cover| = {} but |M| = {} — matching is not maximum",
            cover.size(),
            m.cardinality()
        ));
    }
    Ok(cover)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::Matching;
    use crate::seq::Hk;
    use crate::util::qcheck::{arb_bipartite, forall, Config};
    use crate::MatchingAlgorithm;

    #[test]
    fn koenig_on_small() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        let m = Hk.run_detached(&g, Matching::empty(3, 3)).matching;
        let cover = certify_with_cover(&g, &m).unwrap();
        assert_eq!(cover.size(), 3);
    }

    #[test]
    fn koenig_on_star() {
        // K_{1,4}: cover = the single row, |M| = 1
        let g = from_edges(1, 4, &[(0, 0), (0, 1), (0, 2), (0, 3)]);
        let m = Hk.run_detached(&g, Matching::empty(1, 4)).matching;
        let cover = certify_with_cover(&g, &m).unwrap();
        assert_eq!(cover.size(), 1);
        assert_eq!(cover.rows, vec![0]);
        assert!(cover.cols.is_empty());
    }

    #[test]
    fn koenig_detects_non_maximum_matching() {
        // c1's only neighbor r0 taken by c0 suboptimally
        let g = from_edges(2, 2, &[(0, 0), (1, 0), (0, 1)]);
        let mut m = Matching::empty(2, 2);
        m.join(0, 0); // max is 2 (r1-c0, r0-c1)
        let res = certify_with_cover(&g, &m);
        assert!(res.is_err(), "non-maximum matching must fail certification");
    }

    #[test]
    fn prop_koenig_equals_matching_size() {
        forall(Config::cases(40), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            let g = from_edges(nr, nc, &edges);
            let m = Hk.run_detached(&g, Matching::empty(nr, nc)).matching;
            let cover = certify_with_cover(&g, &m).map_err(|e| e)?;
            if cover.size() != m.cardinality() {
                return Err("König equality violated".into());
            }
            // complement is an independent set: no edge between unreached
            // rows and reached cols — implied by cover validity, but check
            // the sizes too: |IS| = nr + nc - |cover|
            Ok(())
        });
    }

    #[test]
    fn empty_graph_cover_empty() {
        let g = from_edges(4, 4, &[]);
        let m = Matching::empty(4, 4);
        let cover = certify_with_cover(&g, &m).unwrap();
        assert_eq!(cover.size(), 0);
    }
}
