//! The algorithm interface shared by sequential, multicore, GPU-simulated,
//! and XLA-backed matchers, plus the run-record types the evaluation
//! harness consumes.
//!
//! Every run executes against a [`RunCtx`], which carries the three things
//! a serving layer needs and the bare `(graph, init)` signature cannot
//! express:
//! * a [`WorkspacePool`] — size-keyed scratch-buffer reuse, so worker
//!   threads stop re-allocating `bfs_array`/frontier/visited vectors on
//!   every job;
//! * a deadline and a [`CancelToken`] — matchers call
//!   [`RunCtx::checkpoint`] between phases and return early with a
//!   [`RunOutcome::DeadlineExceeded`]/[`RunOutcome::Cancelled`] result
//!   (whose matching is valid but possibly not maximum);
//! * the stats sink ([`RunCtx::stats`]) the run records its counters into.

use super::Matching;
use crate::graph::csr::BipartiteCsr;
use crate::trace::TraceBuf;
use crate::util::pool::WorkspacePool;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Counters every algorithm reports (zeros where not applicable). These
/// regenerate the paper's Fig. 2 (kernel launches per phase) and feed the
/// §Perf analysis.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunStats {
    /// outer iterations (the `while augmenting_path_found` loop of Alg. 1,
    /// or phases of HK/HKDW)
    pub phases: u64,
    /// single-level BFS sweeps / kernel launches (y-axis of Fig. 2)
    pub bfs_kernel_launches: u64,
    /// BFS kernel launches per phase (one entry per outer iteration —
    /// the exact series plotted in Fig. 2)
    pub launches_per_phase: Vec<u32>,
    /// edges scanned (work proxy, robust to the 1-CPU testbed)
    pub edges_scanned: u64,
    /// augmenting paths successfully realized
    pub augmentations: u64,
    /// rows reset by FIXMATCHING (GPU algorithms only)
    pub fixes: u64,
    /// abstract device cycles from the GPU cost model (0 for CPU algos);
    /// serial single-SM view — see `gpu::device` for the model
    pub device_cycles: u64,
    /// parallel-model device cycles (warp work / concurrent warp slots)
    pub device_parallel_cycles: u64,
    /// sequential-fallback augmentations (safety net; expected 0)
    pub fallbacks: u64,
    /// largest BFS frontier a compacted sweep consumed (0 under FullScan)
    pub frontier_peak: u64,
    /// total frontier items consumed across all compacted sweeps — the
    /// per-item scan work a FullScan run would have paid `nc` per launch
    /// for (0 under FullScan)
    pub frontier_total: u64,
    /// total endpoint-worklist items handed to the compacted ALTERNATE —
    /// the rows a FullScan run selects with an `O(nr)` scan per phase
    /// (0 under FullScan)
    pub endpoints_total: u64,
    /// simulated devices the run was sharded across (0 = unsharded)
    pub shards: u64,
    /// 32-bit words moved over the modeled interconnect (sharded runs;
    /// see `gpu::device::EXCHANGE_WORD_COST`)
    pub exchange_words: u64,
    /// interconnect exchange steps executed (sharded runs)
    pub exchange_steps: u64,
}

impl RunStats {
    pub fn record_phase(&mut self, launches_this_phase: u32) {
        self.phases += 1;
        self.bfs_kernel_launches += launches_this_phase as u64;
        self.launches_per_phase.push(launches_this_phase);
    }
}

/// How a run ended. Anything other than [`RunOutcome::Complete`] means the
/// returned matching is *valid* (certifiable structure) but has no
/// maximality guarantee — the coordinator reports such jobs as distinct
/// failures rather than serving a silently suboptimal answer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RunOutcome {
    /// ran to completion; the matching is maximum (algorithm contract)
    #[default]
    Complete,
    /// the context's deadline passed at an inter-phase checkpoint
    DeadlineExceeded,
    /// the context's cancellation token tripped
    Cancelled,
}

impl RunOutcome {
    pub fn is_complete(&self) -> bool {
        matches!(self, RunOutcome::Complete)
    }
}

/// Cooperative cancellation handle. Cloning shares the flag; any clone can
/// cancel, and every matcher observes it at its next inter-phase
/// checkpoint. The coordinator hands one to every in-flight run so a
/// draining service can abandon work it no longer needs.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Per-run execution context: workspace pool, deadline, cancellation, and
/// the stats sink. One `RunCtx` serves one `run` call; the pool inside is
/// shared (via `Arc`) across many contexts, which is where cross-job
/// buffer reuse comes from.
pub struct RunCtx {
    pool: Arc<WorkspacePool>,
    deadline: Option<Instant>,
    cancel: CancelToken,
    /// Counters the running algorithm records into; `finish`/`finish_with`
    /// move them into the returned [`RunResult`].
    pub stats: RunStats,
    /// Span sink, armed per run by whoever wants a trace (executor,
    /// profile subcommand, tests). `None` — the default — costs a single
    /// branch at every instrumentation site; see `crate::trace`.
    trace: Option<Box<TraceBuf>>,
}

impl RunCtx {
    pub fn new(pool: Arc<WorkspacePool>) -> Self {
        Self {
            pool,
            deadline: None,
            cancel: CancelToken::new(),
            stats: RunStats::default(),
            trace: None,
        }
    }

    /// A throwaway context: private pool, no deadline, fresh token. What
    /// [`MatchingAlgorithm::run_detached`] uses.
    pub fn detached() -> Self {
        Self::new(Arc::new(WorkspacePool::new()))
    }

    /// Set the deadline `budget` from now.
    pub fn with_deadline_in(mut self, budget: Duration) -> Self {
        self.deadline = Some(Instant::now() + budget);
        self
    }

    pub fn set_deadline(&mut self, deadline: Option<Instant>) {
        self.deadline = deadline;
    }

    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = token;
        self
    }

    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    pub fn pool(&self) -> &WorkspacePool {
        &self.pool
    }

    /// Sub-context for a nested matcher (the fallback tails some matchers
    /// run): shares the pool, deadline, and cancellation token, but
    /// collects its own stats so the caller controls the merge.
    pub fn fork(&self) -> RunCtx {
        RunCtx {
            pool: self.pool.clone(),
            deadline: self.deadline,
            cancel: self.cancel.clone(),
            stats: RunStats::default(),
            // span sinks are not forked: nested fallback runs merge into
            // the caller's *stats*, and their phase structure is the
            // caller's to narrate (a fork cannot own half the buffer)
            trace: None,
        }
    }

    // -- tracing ----------------------------------------------------------

    /// Arm span recording for this run. The executor (or the profile
    /// subcommand) hands the buffer in before `run` and takes it back
    /// with [`RunCtx::take_trace`] after.
    pub fn arm_trace(&mut self, buf: Box<TraceBuf>) {
        self.trace = Some(buf);
    }

    pub fn take_trace(&mut self) -> Option<Box<TraceBuf>> {
        self.trace.take()
    }

    /// The armed span sink, if any. Matcher instrumentation sites call
    /// this and do nothing when it returns `None` — that single branch is
    /// the entire disarmed cost.
    pub fn trace(&mut self) -> Option<&mut TraceBuf> {
        self.trace.as_deref_mut()
    }

    /// Record one completed matcher phase: updates the run's counters
    /// (phases, kernel launches, the Fig. 2 `launches_per_phase` series)
    /// and — when tracing is armed — emits the matching `"phase"` span.
    /// Matchers call this instead of touching `stats.record_phase`
    /// directly so the span and the counter can never disagree.
    pub fn record_phase(&mut self, launches_this_phase: u32) {
        self.stats.record_phase(launches_this_phase);
        if let Some(t) = self.trace.as_deref_mut() {
            t.phase_span(self.stats.phases - 1, launches_this_phase);
        }
    }

    /// Deadline/cancellation check — matchers call this between phases
    /// (never inside a kernel) and return early with the reported outcome.
    /// Cancellation wins over an expired deadline when both hold.
    pub fn checkpoint(&self) -> Option<RunOutcome> {
        if self.cancel.is_cancelled() {
            return Some(RunOutcome::Cancelled);
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                return Some(RunOutcome::DeadlineExceeded);
            }
        }
        None
    }

    pub fn take_stats(&mut self) -> RunStats {
        std::mem::take(&mut self.stats)
    }

    /// Seal a completed run: moves the recorded stats into the result.
    pub fn finish(&mut self, matching: Matching) -> RunResult {
        self.finish_with(matching, RunOutcome::Complete)
    }

    /// Seal a run with an explicit outcome (tripped deadline/cancellation).
    pub fn finish_with(&mut self, matching: Matching, outcome: RunOutcome) -> RunResult {
        RunResult { matching, stats: self.take_stats(), outcome }
    }

    // -- workspace leases (delegates to the shared pool) ------------------

    pub fn lease_i32(&self, len: usize, fill: i32) -> Vec<i32> {
        self.pool.lease_i32(len, fill)
    }

    pub fn give_i32(&self, v: Vec<i32>) {
        self.pool.give_i32(v)
    }

    pub fn lease_u32(&self, len: usize, fill: u32) -> Vec<u32> {
        self.pool.lease_u32(len, fill)
    }

    pub fn give_u32(&self, v: Vec<u32>) {
        self.pool.give_u32(v)
    }

    pub fn lease_bool(&self, len: usize, fill: bool) -> Vec<bool> {
        self.pool.lease_bool(len, fill)
    }

    pub fn give_bool(&self, v: Vec<bool>) {
        self.pool.give_bool(v)
    }

    /// Lease an *empty* worklist with at least `cap_hint` capacity. The
    /// hint makes the pool pick a size-fitted buffer — leasing at length
    /// 0 would grab the smallest shelved one, which the first pushes of a
    /// large run immediately outgrow — and nothing is filled (worklists
    /// only ever push).
    pub fn lease_worklist_u32(&self, cap_hint: usize) -> Vec<u32> {
        self.pool.lease_u32_worklist(cap_hint)
    }
}

/// Result of one algorithm execution (timing is measured by the caller so
/// the policy — warmups, repetitions — lives in one place, the harness).
#[derive(Debug, Clone)]
pub struct RunResult {
    pub matching: Matching,
    pub stats: RunStats,
    /// `Complete`, or how the run was interrupted (see [`RunOutcome`]).
    pub outcome: RunOutcome,
}

impl RunResult {
    pub fn new(matching: Matching) -> Self {
        Self { matching, stats: RunStats::default(), outcome: RunOutcome::Complete }
    }

    pub fn with_stats(matching: Matching, stats: RunStats) -> Self {
        Self { matching, stats, outcome: RunOutcome::Complete }
    }

    pub fn is_complete(&self) -> bool {
        self.outcome.is_complete()
    }
}

/// A maximum-cardinality matching algorithm. `run` must return a matching
/// that is *maximum* (certified by the test suite), starting from the given
/// initial matching (the common cheap-matching initialization of §4) —
/// unless the context trips first, in which case the run returns its
/// best-so-far valid matching tagged with the interrupting [`RunOutcome`].
pub trait MatchingAlgorithm: Send + Sync {
    /// Stable identifier used by the CLI, the harness, and result files.
    fn name(&self) -> String;

    /// Compute a maximum matching extending `init`: scratch buffers come
    /// from `ctx`'s workspace pool, counters go to `ctx.stats`, and the
    /// context's deadline/cancellation is honoured between phases.
    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult;

    /// Convenience wrapper: run with a throwaway context (private pool, no
    /// deadline). One-shot callers, tests, and benches use this.
    fn run_detached(&self, g: &BipartiteCsr, init: Matching) -> RunResult {
        self.run(g, init, &mut RunCtx::detached())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_phase_accumulates() {
        let mut s = RunStats::default();
        s.record_phase(3);
        s.record_phase(5);
        assert_eq!(s.phases, 2);
        assert_eq!(s.bfs_kernel_launches, 8);
        assert_eq!(s.launches_per_phase, vec![3, 5]);
    }

    #[test]
    fn run_result_constructors() {
        let m = Matching::empty(2, 2);
        let r = RunResult::new(m.clone());
        assert_eq!(r.stats, RunStats::default());
        assert!(r.is_complete());
        let mut s = RunStats::default();
        s.augmentations = 4;
        let r2 = RunResult::with_stats(m, s.clone());
        assert_eq!(r2.stats, s);
        assert_eq!(r2.outcome, RunOutcome::Complete);
    }

    #[test]
    fn checkpoint_clear_by_default() {
        let ctx = RunCtx::detached();
        assert_eq!(ctx.checkpoint(), None);
    }

    #[test]
    fn checkpoint_reports_cancellation() {
        let ctx = RunCtx::detached();
        let token = ctx.cancel_token();
        assert_eq!(ctx.checkpoint(), None);
        token.cancel();
        assert_eq!(ctx.checkpoint(), Some(RunOutcome::Cancelled));
        assert!(token.is_cancelled());
    }

    #[test]
    fn checkpoint_reports_expired_deadline() {
        let ctx = RunCtx::detached().with_deadline_in(std::time::Duration::ZERO);
        assert_eq!(ctx.checkpoint(), Some(RunOutcome::DeadlineExceeded));
        let mut ctx = RunCtx::detached().with_deadline_in(std::time::Duration::from_secs(3600));
        assert_eq!(ctx.checkpoint(), None);
        ctx.set_deadline(None);
        assert_eq!(ctx.checkpoint(), None);
    }

    #[test]
    fn cancellation_wins_over_deadline() {
        let ctx = RunCtx::detached().with_deadline_in(std::time::Duration::ZERO);
        ctx.cancel_token().cancel();
        assert_eq!(ctx.checkpoint(), Some(RunOutcome::Cancelled));
    }

    #[test]
    fn fork_shares_pool_and_token_but_not_stats() {
        let mut ctx = RunCtx::detached().with_deadline_in(std::time::Duration::from_secs(3600));
        ctx.stats.augmentations = 5;
        let sub = ctx.fork();
        assert_eq!(sub.stats, RunStats::default());
        assert_eq!(sub.checkpoint(), None);
        ctx.cancel_token().cancel();
        assert_eq!(sub.checkpoint(), Some(RunOutcome::Cancelled), "token is shared");
        // pool is shared: a buffer given back via the fork is leasable here
        sub.give_i32(vec![0; 64]);
        let _ = ctx.lease_i32(64, -1);
        assert_eq!(ctx.pool().reuses(), 1);
    }

    #[test]
    fn ctx_record_phase_emits_spans_agreeing_with_stats() {
        let mut ctx = RunCtx::detached();
        assert!(ctx.trace().is_none(), "disarmed by default");
        ctx.arm_trace(crate::trace::TraceBuf::new());
        ctx.record_phase(3);
        ctx.record_phase(1);
        let buf = ctx.take_trace().expect("armed buffer comes back");
        let spans: Vec<_> = buf.spans().iter().filter(|s| s.cat == "phase").collect();
        let launches: Vec<u64> = spans
            .iter()
            .map(|s| s.args.iter().find(|(k, _)| *k == "launches").unwrap().1)
            .collect();
        assert_eq!(launches, vec![3, 1]);
        assert_eq!(ctx.stats.launches_per_phase, vec![3, 1]);
        assert!(ctx.trace().is_none(), "take_trace disarms");
        // fork never inherits the sink
        ctx.arm_trace(crate::trace::TraceBuf::new());
        let mut sub = ctx.fork();
        assert!(sub.trace().is_none());
    }

    #[test]
    fn finish_moves_stats_and_sets_outcome() {
        let mut ctx = RunCtx::detached();
        ctx.stats.record_phase(2);
        let r = ctx.finish(Matching::empty(1, 1));
        assert_eq!(r.stats.phases, 1);
        assert!(r.is_complete());
        assert_eq!(ctx.stats, RunStats::default(), "finish drains the sink");
        let r2 = ctx.finish_with(Matching::empty(1, 1), RunOutcome::DeadlineExceeded);
        assert!(!r2.is_complete());
    }
}
