//! The algorithm interface shared by sequential, multicore, GPU-simulated,
//! and XLA-backed matchers, plus the run-record types the evaluation
//! harness consumes.

use super::Matching;
use crate::graph::csr::BipartiteCsr;

/// Counters every algorithm reports (zeros where not applicable). These
/// regenerate the paper's Fig. 2 (kernel launches per phase) and feed the
/// §Perf analysis.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunStats {
    /// outer iterations (the `while augmenting_path_found` loop of Alg. 1,
    /// or phases of HK/HKDW)
    pub phases: u64,
    /// single-level BFS sweeps / kernel launches (y-axis of Fig. 2)
    pub bfs_kernel_launches: u64,
    /// BFS kernel launches per phase (one entry per outer iteration —
    /// the exact series plotted in Fig. 2)
    pub launches_per_phase: Vec<u32>,
    /// edges scanned (work proxy, robust to the 1-CPU testbed)
    pub edges_scanned: u64,
    /// augmenting paths successfully realized
    pub augmentations: u64,
    /// rows reset by FIXMATCHING (GPU algorithms only)
    pub fixes: u64,
    /// abstract device cycles from the GPU cost model (0 for CPU algos);
    /// serial single-SM view — see `gpu::device` for the model
    pub device_cycles: u64,
    /// parallel-model device cycles (warp work / concurrent warp slots)
    pub device_parallel_cycles: u64,
    /// sequential-fallback augmentations (safety net; expected 0)
    pub fallbacks: u64,
    /// largest BFS frontier a compacted sweep consumed (0 under FullScan)
    pub frontier_peak: u64,
    /// total frontier items consumed across all compacted sweeps — the
    /// per-item scan work a FullScan run would have paid `nc` per launch
    /// for (0 under FullScan)
    pub frontier_total: u64,
    /// total endpoint-worklist items handed to the compacted ALTERNATE —
    /// the rows a FullScan run selects with an `O(nr)` scan per phase
    /// (0 under FullScan)
    pub endpoints_total: u64,
}

impl RunStats {
    pub fn record_phase(&mut self, launches_this_phase: u32) {
        self.phases += 1;
        self.bfs_kernel_launches += launches_this_phase as u64;
        self.launches_per_phase.push(launches_this_phase);
    }
}

/// Result of one algorithm execution (timing is measured by the caller so
/// the policy — warmups, repetitions — lives in one place, the harness).
#[derive(Debug, Clone)]
pub struct RunResult {
    pub matching: Matching,
    pub stats: RunStats,
}

impl RunResult {
    pub fn new(matching: Matching) -> Self {
        Self { matching, stats: RunStats::default() }
    }

    pub fn with_stats(matching: Matching, stats: RunStats) -> Self {
        Self { matching, stats }
    }
}

/// A maximum-cardinality matching algorithm. `run` must return a matching
/// that is *maximum* (certified by the test suite), starting from the given
/// initial matching (the common cheap-matching initialization of §4).
pub trait MatchingAlgorithm: Send + Sync {
    /// Stable identifier used by the CLI, the harness, and result files.
    fn name(&self) -> String;

    /// Compute a maximum matching, extending `init`.
    fn run(&self, g: &BipartiteCsr, init: Matching) -> RunResult;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_phase_accumulates() {
        let mut s = RunStats::default();
        s.record_phase(3);
        s.record_phase(5);
        assert_eq!(s.phases, 2);
        assert_eq!(s.bfs_kernel_launches, 8);
        assert_eq!(s.launches_per_phase, vec![3, 5]);
    }

    #[test]
    fn run_result_constructors() {
        let m = Matching::empty(2, 2);
        let r = RunResult::new(m.clone());
        assert_eq!(r.stats, RunStats::default());
        let mut s = RunStats::default();
        s.augmentations = 4;
        let r2 = RunResult::with_stats(m, s.clone());
        assert_eq!(r2.stats, s);
    }
}
