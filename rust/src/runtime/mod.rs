//! PJRT runtime: artifact discovery ([`artifacts`]) and the compile/execute
//! engine ([`engine`]). `Engine::open` → `load(name)` → `run_i32(...)`;
//! see `/opt/xla-example/load_hlo` for the minimal pattern this extends.

pub mod artifacts;
pub mod engine;

pub use artifacts::{Artifact, ArtifactKind, Manifest};
pub use engine::{Engine, Executable};
