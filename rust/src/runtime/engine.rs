//! The PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` once, compiles them on the CPU PJRT client, and
//! executes them from the L3 hot path. Python is never involved at
//! runtime — the artifacts directory is the entire interface.

use super::artifacts::{Artifact, ArtifactKind, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A compiled artifact ready to execute.
pub struct Executable {
    pub meta: Artifact,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with int32 host buffers; returns the flattened tuple of
    /// int32 outputs.
    pub fn run_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<Vec<i32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let lit = if dims.len() == 1 && dims[0] as usize == data.len() {
                lit
            } else {
                lit.reshape(dims).context("reshape input literal")?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("pjrt execute")?;
        let out = result[0][0]
            .to_literal_sync()
            .context("device-to-host transfer")?;
        // aot.py lowers with return_tuple=True
        let parts = out.to_tuple().context("decompose output tuple")?;
        let mut vecs = Vec::with_capacity(parts.len());
        for p in parts {
            vecs.push(p.to_vec::<i32>().context("output to_vec")?);
        }
        Ok(vecs)
    }
}

/// PJRT client + executable cache keyed by artifact name. Compilation
/// happens once per artifact per process; `run_i32` afterwards is
/// Python-free and allocation-light.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

// SAFETY: the PJRT CPU client is internally synchronized; the xla crate
// just doesn't mark its opaque handles Send/Sync. We serialize compile
// calls through the cache mutex and PJRT execute is thread-safe on CPU.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Open the artifacts directory (reads `manifest.json`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, dir: dir.to_path_buf(), cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifacts location: `$BIMATCH_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("BIMATCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(Path::new(&dir))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Smallest bucket of `kind` that fits (nc_packed, nr, max_k).
    pub fn find_bucket(&self, kind: ArtifactKind, nc: usize, nr: usize, k: usize) -> Option<&Artifact> {
        self.manifest.find_bucket(kind, nc, nr, k)
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let arc = std::sync::Arc::new(Executable { meta, exe });
        cache.insert(name.to_string(), arc.clone());
        Ok(arc)
    }
}

#[cfg(test)]
mod tests {
    // engine tests that need real artifacts live in rust/tests/
    // xla_roundtrip.rs (they require `make artifacts` to have run); here
    // only the pure-logic pieces are covered.
    use super::*;

    #[test]
    fn open_missing_dir_fails_cleanly() {
        match Engine::open(Path::new("/definitely/not/here")) {
            Ok(_) => panic!("open must fail on a missing directory"),
            Err(err) => assert!(format!("{err:#}").contains("manifest")),
        }
    }
}
