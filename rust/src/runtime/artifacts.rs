//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`). A hand-rolled minimal JSON reader — the
//! offline environment has no serde_json, and the manifest grammar is a
//! fixed, flat shape we control end to end.

use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// one GPUBFS level expansion
    BfsLevel,
    /// the full APFB matching loop
    ApfbFull,
}

impl ArtifactKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "bfs_level" => Some(Self::BfsLevel),
            "apfb_full" => Some(Self::ApfbFull),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub kind: ArtifactKind,
    pub file: String,
    pub nc: usize,
    pub nr: usize,
    pub k: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse the fixed manifest shape. Tolerates whitespace/ordering but
    /// not arbitrary JSON (strings in our grammar never contain escapes).
    pub fn parse(text: &str) -> Result<Self> {
        let objs = extract_objects(text, "\"artifacts\"")?;
        let mut artifacts = Vec::with_capacity(objs.len());
        for o in objs {
            let name = get_string(&o, "name")?;
            let kind_s = get_string(&o, "kind")?;
            let kind = ArtifactKind::from_str(&kind_s)
                .ok_or_else(|| anyhow!("unknown artifact kind {kind_s}"))?;
            artifacts.push(Artifact {
                name,
                kind,
                file: get_string(&o, "file")?,
                nc: get_usize(&o, "nc")?,
                nr: get_usize(&o, "nr")?,
                k: get_usize(&o, "k")?,
            });
        }
        Ok(Self { artifacts })
    }

    /// Smallest (by nc then nr then k) artifact of `kind` with
    /// `nc >= need_nc && nr >= need_nr && k == need_k`. K must match
    /// exactly: the ELL packer targets the bucket's K.
    pub fn find_bucket(
        &self,
        kind: ArtifactKind,
        need_nc: usize,
        need_nr: usize,
        need_k: usize,
    ) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.nc >= need_nc && a.nr >= need_nr && a.k == need_k)
            .min_by_key(|a| (a.nc, a.nr, a.k))
    }

    /// All distinct (nc, nr, k) bucket shapes present.
    pub fn buckets(&self) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<_> = self.artifacts.iter().map(|a| (a.nc, a.nr, a.k)).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Pull out the `{...}` objects inside the array following `key`.
fn extract_objects(text: &str, key: &str) -> Result<Vec<String>> {
    let start = text
        .find(key)
        .ok_or_else(|| anyhow!("manifest missing {key}"))?;
    let rest = &text[start..];
    let open = rest.find('[').ok_or_else(|| anyhow!("no array after {key}"))?;
    let mut objs = Vec::new();
    let mut depth = 0usize;
    let mut obj_start = None;
    for (i, ch) in rest[open..].char_indices() {
        match ch {
            '{' => {
                if depth == 0 {
                    obj_start = Some(open + i);
                }
                depth += 1;
            }
            '}' => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| anyhow!("unbalanced braces"))?;
                if depth == 0 {
                    let s = obj_start.take().ok_or_else(|| anyhow!("brace underflow"))?;
                    objs.push(rest[s..=open + i].to_string());
                }
            }
            ']' if depth == 0 => break,
            _ => {}
        }
    }
    if depth != 0 {
        bail!("unterminated object in manifest");
    }
    Ok(objs)
}

fn get_string(obj: &str, key: &str) -> Result<String> {
    let pat = format!("\"{key}\"");
    let kpos = obj.find(&pat).ok_or_else(|| anyhow!("missing key {key}"))?;
    let rest = &obj[kpos + pat.len()..];
    let colon = rest.find(':').ok_or_else(|| anyhow!("missing : after {key}"))?;
    let rest = rest[colon + 1..].trim_start();
    if !rest.starts_with('"') {
        bail!("key {key} is not a string");
    }
    let end = rest[1..]
        .find('"')
        .ok_or_else(|| anyhow!("unterminated string for {key}"))?;
    Ok(rest[1..1 + end].to_string())
}

fn get_usize(obj: &str, key: &str) -> Result<usize> {
    let pat = format!("\"{key}\"");
    let kpos = obj.find(&pat).ok_or_else(|| anyhow!("missing key {key}"))?;
    let rest = &obj[kpos + pat.len()..];
    let colon = rest.find(':').ok_or_else(|| anyhow!("missing : after {key}"))?;
    let rest = rest[colon + 1..].trim_start();
    let end = rest
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(rest.len());
    rest[..end]
        .parse::<usize>()
        .with_context(|| format!("parsing number for {key}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "l0": 2,
      "artifacts": [
        {"name": "bfs_level_64x64x4", "kind": "bfs_level",
         "file": "bfs_level_64x64x4.hlo.txt", "nc": 64, "nr": 64, "k": 4,
         "bytes": 123},
        {"name": "apfb_full_1024x512x8", "kind": "apfb_full",
         "file": "apfb_full_1024x512x8.hlo.txt", "nc": 1024, "nr": 512,
         "k": 8, "bytes": 456}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].name, "bfs_level_64x64x4");
        assert_eq!(m.artifacts[0].kind, ArtifactKind::BfsLevel);
        assert_eq!(m.artifacts[1].nc, 1024);
        assert_eq!(m.artifacts[1].nr, 512);
        assert_eq!(m.artifacts[1].k, 8);
    }

    #[test]
    fn find_bucket_smallest_fit() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.find_bucket(ArtifactKind::ApfbFull, 100, 100, 8).unwrap();
        assert_eq!(a.name, "apfb_full_1024x512x8");
        // K mismatch -> none
        assert!(m.find_bucket(ArtifactKind::ApfbFull, 100, 100, 4).is_none());
        // too big -> none
        assert!(m.find_bucket(ArtifactKind::BfsLevel, 100, 100, 4).is_none());
    }

    #[test]
    fn buckets_deduped() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.buckets(), vec![(64, 64, 4), (1024, 512, 8)]);
    }

    #[test]
    fn malformed_rejected() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }
}
