//! Command-line interface (hand-rolled parser; clap is unavailable
//! offline). `bimatch help` prints usage.

use crate::coordinator::job::{GraphSource, MatchJob};
use crate::coordinator::{registry, AlgoSpec, Executor, Metrics, Server, ServerCfg};
use crate::persist::replicate::AckMode;
use crate::graph::gen::Family;
use crate::harness::{catalog, Scale};
use crate::matching::init::InitHeuristic;
use crate::runtime::Engine;
use std::collections::HashMap;
use std::sync::Arc;

pub const USAGE: &str = "\
bimatch — GPU-accelerated maximum cardinality bipartite matching (Deveci et al. 2013)

USAGE:
  bimatch run   (--family <name> --n <int> [--seed <int>] [--permute] | --mtx <path>)
                [--algo <name>|auto] [--init none|cheap|ks] [--no-certify]
                [--timeout-ms <int>]   (deadline over the whole job — load,
                init, matching; a tripped run fails with a distinct
                timeout error instead of returning a possibly
                non-maximum matching)
                [--frontier fullscan|compacted]   (gpu:* algos; compacted =
                worklist-driven BFS sweeps + endpoint-list ALTERNATE, the
                \"-FC\" registry variants — the router's default GPU
                pick. The flag edits the frontier field of whichever
                gpu:* spec runs, named or auto-routed; CPU-routed graphs
                keep their pfp/dfs pick, so `--frontier fullscan` forces
                the paper-faithful variant only where a GPU algorithm
                actually runs)
  bimatch gen    --family <name> --n <int> [--seed <int>] [--permute] --out <path.mtx>
  bimatch verify --mtx <path>          cross-check several algorithms on a file
  bimatch serve  [--addr <ip:port>] [--data-dir <path>] [--max-graphs <n>]
                [--replicate-from <ip:port>] [--ack-mode local|quorum]
                [--snapshot-shards <k>] [--slow-ms <int>] [--trace-cap <n>]
                [--log-level debug|info|warn|error|off]
                TCP line-protocol matching service
                (one-shot MATCH plus the incremental verbs: LOAD name=…
                installs a graph server-side, UPDATE name=… add=r:c,…
                del=r:c,… addcols=r;r|… addrows=c;c|… applies a delta
                batch and repairs the maintained matching via seeded
                augmentation, MATCH name=… re-serves the cached maximum,
                DROP name=… evicts; GRAPHS lists stored graphs — see
                coordinator::server docs. --data-dir makes stored graphs
                durable: UPDATEs hit a per-graph write-ahead log fsync'd
                before the OK reply, threshold rebuilds piggyback
                snapshots, restart recovers every graph by replaying the
                log tail and repairing — not recomputing — its matching,
                and SAVE name=… forces a snapshot now. --max-graphs caps
                the in-memory store: LRU graphs are snapshotted to the
                data dir and transparently reloaded on their next MATCH.
                --replicate-from starts a read replica: it tails the
                primary's WAL-frame stream, replays it through the crash-
                recovery path, serves MATCH name=… and rejects writes;
                PROMOTE over the wire fails it over (epoch-fencing the
                old primary). --ack-mode quorum makes the primary hold
                each write's OK until a follower acked its frame, so a
                primary crash can never lose an acked update.
                --snapshot-shards k writes each snapshot as k per-shard
                files (column-partitioned like shard<k>: execution) under
                the same per-graph WAL; recovery and fsck read either
                layout. Observability: every job is span-traced into a
                ring (--trace-cap entries, default 256, 0 disarms);
                TRACE [name=<g>] [last=<n>] streams the newest traces as
                JSON lines, METRICS serves Prometheus text (process,
                per-spec, and per-graph families), STATS graph=<g> gives
                one graph's serving breakdown, and --slow-ms emits a
                warn-level slow_job event with a compact span summary
                for any job at or over the threshold (counted as jobs:
                slow= in STATS). Lifecycle events (connections, drain,
                eviction, recovery, promotion, replication) are one JSON
                object per line on stderr — and in
                <data-dir>/events.jsonl when durable — filtered by
                --log-level (default: BIMATCH_LOG or info). The flight
                recorder keeps the most recent events in a ring
                regardless of level: a panic dumps it to
                <data-dir>/flightrec/, a background flusher refreshes
                flightrec/latest.jsonl about once a second (so even
                SIGKILL leaves a postmortem), and the DUMP verb writes a
                dump on demand. HEALTH serves a one-line liveness
                summary (role, epoch, version, git, uptime).
                SIGTERM or SIGINT triggers a graceful stop:
                in-flight requests drain, WALs fsync, then the process
                exits)
  bimatch profile (--family <name> --n <int> [--seed <int>] [--permute] | --mtx <path>)
                [--algo <name>|auto] [--init none|cheap|ks] [--no-certify]
                [--out <path.json>]
                run one job with span tracing armed and emit the full
                kernel/phase timeline as a Chrome trace_event JSON
                document (load chrome://tracing or ui.perfetto.dev; the
                host process shows wall-clock \u{b5}s spans, the device
                process renders one modeled cycle per \u{b5}s — the
                paper's Fig. 2 per-phase kernel breakdown, reconstructed
                from a run). Without --out the document goes to stdout
                (diagnostics go to stderr, so piping stays clean)
  bimatch fsck   --data-dir <path>     offline durability check: verifies WAL
                frame checksums, incarnation monotonicity, and
                snapshot↔WAL consistency for every graph in the data
                dir, without modifying anything. Findings are graded
                repairable (recovery handles them: torn final frames,
                superseded corrupt snapshots, unfinished DROPs) vs
                FATAL (recovery would lose acknowledged state). Exit 0
                when recoverable, 1 on any FATAL finding
  bimatch bench-report [--dir <path>] [--out <path>] [--baseline <path>]
                [--max-regress <fraction>]
                merge the per-bench telemetry JSON the bench binaries
                write under target/bench/ (schema bimatch-bench/1) into
                one BENCH_<date>.json document (schema
                bimatch-bench-report/1). With --baseline, compare every
                shared metric against the committed baseline report and
                exit 1 if any regresses by more than --max-regress
                (default 0.20), respecting each metric's
                higher_is_better direction
  bimatch algos                        list registered algorithms
                (also: bimatch --list-algos — CI diffs this against the
                registry-names.txt golden file)
  bimatch catalog                      list the benchmark instance catalog
  bimatch artifacts-check              compile every artifact on the PJRT client
  bimatch help

Algorithm names are the AlgoSpec wire format: sequential (hk hkdw pfp dfs bfs
pr), multicore with optional thread count (p-hk p-pfp p-dbfs, e.g. p-dbfs@4),
gpu:<VARIANT>[-FC], xla:apfb-full, xla:bfs-level-hybrid; `gpu` = paper's best.
Generator families: road delaunay hugetrace rgg kron social amazon web banded uniform
Env: BIMATCH_THREADS (host pool size), BIMATCH_DEVICE_PAR (host threads for ALL
GPU-simulator kernels: disjoint ones run bit-identically, racy ones — BFS
sweeps, ALTERNATE — go through the atomic CAS path with identical final
cardinality; combines freely with either --frontier mode),
BIMATCH_SCALE=small|large (bench catalog)";

/// Parse `--key value` / `--flag` style arguments.
fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut map = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let boolean = matches!(key, "permute" | "no-certify" | "help");
            if !boolean && i + 1 < args.len() {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "1".into());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (map, positional)
}

fn engine_if_available() -> Option<Arc<Engine>> {
    Engine::open_default().ok().map(Arc::new)
}

pub fn main_with_args(args: Vec<String>) -> i32 {
    let Some(cmd) = args.first().cloned() else {
        println!("{USAGE}");
        return 2;
    };
    let (flags, _) = parse_flags(&args[1..]);
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "gen" => cmd_gen(&flags),
        "verify" => cmd_verify(&flags),
        "serve" => cmd_serve(&flags),
        "profile" => cmd_profile(&flags),
        "fsck" => cmd_fsck(&flags),
        "bench-report" => cmd_bench_report(&flags),
        "algos" | "--list-algos" => {
            for n in registry::all_names() {
                println!("{n}");
            }
            0
        }
        "catalog" => {
            let scale = Scale::from_env();
            for i in catalog::original(scale).iter().chain(catalog::rcp(scale).iter()) {
                println!("{}", i.name());
            }
            0
        }
        "artifacts-check" => cmd_artifacts_check(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            0
        }
        other => {
            eprintln!("unknown command {other}\n{USAGE}");
            2
        }
    }
}

fn source_from_flags(flags: &HashMap<String, String>) -> Result<GraphSource, String> {
    if let Some(path) = flags.get("mtx") {
        return Ok(GraphSource::MtxFile(path.clone()));
    }
    let family = flags
        .get("family")
        .and_then(|f| Family::from_name(f))
        .ok_or("missing or unknown --family (see `bimatch help`)")?;
    let n: usize = flags
        .get("n")
        .ok_or("missing --n")?
        .parse()
        .map_err(|e| format!("bad --n: {e}"))?;
    let seed: u64 = flags
        .get("seed")
        .map(|s| s.parse())
        .transpose()
        .map_err(|e| format!("bad --seed: {e}"))?
        .unwrap_or(1);
    Ok(GraphSource::Generate { family, n, seed, permute: flags.contains_key("permute") })
}

fn cmd_run(flags: &HashMap<String, String>) -> i32 {
    let source = match source_from_flags(flags) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut job = MatchJob::new(0, source);
    // parse --algo at the CLI boundary: malformed names never build a job
    let spec = match flags.get("algo").filter(|a| a.as_str() != "auto") {
        Some(name) => match name.parse::<AlgoSpec>() {
            Ok(spec) => Some(spec),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        None => None,
    };
    if let Some(mode) = flags.get("frontier") {
        use crate::gpu::FrontierMode;
        let Some(fm) = FrontierMode::from_name(mode) else {
            eprintln!("unknown --frontier {mode} (fullscan|compacted)");
            return 2;
        };
        // with an explicit algo, --frontier only makes sense for GPU specs
        if let Some(spec) = &spec {
            if !spec.is_gpu() {
                eprintln!("--frontier applies to gpu:* algorithms, not {spec}");
                return 2;
            }
        }
        // the override is applied by the executor *after* routing, as a
        // typed field edit: a GPU spec (named or auto-routed, including
        // the router's "-FC" default) gets the requested frontier mode,
        // while CPU-routed graphs keep their pfp/dfs pick
        job = job.with_frontier(fm);
    }
    if let Some(spec) = spec {
        job = job.with_spec(spec);
    }
    if let Some(init) = flags.get("init") {
        match InitHeuristic::from_name(init) {
            Some(h) => job.init = h,
            None => {
                eprintln!("unknown --init {init}");
                return 2;
            }
        }
    }
    if let Some(t) = flags.get("timeout-ms") {
        match t.parse::<u64>() {
            Ok(ms) => job = job.with_timeout_ms(ms),
            Err(e) => {
                eprintln!("bad --timeout-ms: {e}");
                return 2;
            }
        }
    }
    job.certify = !flags.contains_key("no-certify");
    let exec = Executor::new(engine_if_available(), Arc::new(Metrics::new()));
    let o = exec.execute(&job);
    match o.error {
        Some(e) => {
            eprintln!("ERROR: {e}");
            1
        }
        None => {
            println!(
                "graph: {} rows x {} cols, {} edges\nalgorithm: {}\ninit cardinality: {}\n\
                 maximum matching: {}{}\nload {:.4}s  init {:.4}s  match {:.4}s  ({} phases)",
                o.nr,
                o.nc,
                o.n_edges,
                o.algo,
                o.init_cardinality,
                o.cardinality,
                if o.certified { " (certified maximum)" } else { "" },
                o.t_load,
                o.t_init,
                o.t_match,
                o.phases,
            );
            0
        }
    }
}

fn cmd_gen(flags: &HashMap<String, String>) -> i32 {
    let source = match source_from_flags(flags) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let Some(out) = flags.get("out") else {
        eprintln!("missing --out");
        return 2;
    };
    let GraphSource::Generate { family, n, seed, permute } = source else {
        eprintln!("gen requires --family/--n, not --mtx");
        return 2;
    };
    let g = family.generate(n, seed);
    let g = if permute { crate::graph::random_permute(&g, seed ^ 0x5EED) } else { g };
    match crate::graph::mtx::write_mtx(&g, std::path::Path::new(out)) {
        Ok(()) => {
            println!("wrote {} ({} x {}, {} edges)", out, g.nr, g.nc, g.n_edges());
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

fn cmd_verify(flags: &HashMap<String, String>) -> i32 {
    let Some(path) = flags.get("mtx") else {
        eprintln!("verify requires --mtx <path>");
        return 2;
    };
    let g = match crate::graph::mtx::read_mtx(std::path::Path::new(path)) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("read failed: {e}");
            return 1;
        }
    };
    let init = InitHeuristic::Cheap.run(&g);
    let mut card = None;
    for name in ["hk", "pfp", "pr", "gpu:APFB-GPUBFS-WR-CT", "p-dbfs"] {
        let algo = registry::build_named(name, None).unwrap();
        let r = algo.run_detached(&g, init.clone());
        if let Err(e) = r.matching.certify(&g) {
            eprintln!("{name}: CERTIFICATION FAILED: {e}");
            return 1;
        }
        let c = r.matching.cardinality();
        println!("{name}: cardinality {c} (certified)");
        if let Some(prev) = card {
            if prev != c {
                eprintln!("DISAGREEMENT: {prev} vs {c}");
                return 1;
            }
        }
        card = Some(c);
    }
    println!("all algorithms agree");
    0
}

/// Run one traced job and emit its Chrome `trace_event` timeline — the
/// paper's Fig. 2 per-phase kernel breakdown, reconstructed from a live
/// run. JSON goes to `--out` (or stdout); diagnostics go to stderr.
fn cmd_profile(flags: &HashMap<String, String>) -> i32 {
    let source = match source_from_flags(flags) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut job = MatchJob::new(0, source);
    if let Some(name) = flags.get("algo").filter(|a| a.as_str() != "auto") {
        match name.parse::<AlgoSpec>() {
            Ok(spec) => job = job.with_spec(spec),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    if let Some(init) = flags.get("init") {
        match InitHeuristic::from_name(init) {
            Some(h) => job.init = h,
            None => {
                eprintln!("unknown --init {init}");
                return 2;
            }
        }
    }
    job.certify = !flags.contains_key("no-certify");
    // a one-slot ring: the single job's trace is all we keep
    let ring = crate::trace::TraceRing::new(1);
    let exec = Executor::new(engine_if_available(), Arc::new(Metrics::new()))
        .with_trace_ring(ring.clone());
    let o = exec.execute(&job);
    if let Some(e) = o.error {
        eprintln!("ERROR: {e}");
        return 1;
    }
    let traces = ring.recent(None, 1);
    let Some(t) = traces.first() else {
        eprintln!("no trace captured");
        return 1;
    };
    let doc = t.to_chrome_trace();
    eprintln!(
        "profiled {} on {}x{} ({} edges): cardinality {}, {} phases, {} kernel launches, \
         {} spans ({} dropped)",
        t.algo, o.nr, o.nc, o.n_edges, o.cardinality, t.phases, t.launches,
        t.spans.len(), t.dropped_spans,
    );
    match flags.get("out") {
        Some(path) => match std::fs::write(path, &doc) {
            Ok(()) => {
                eprintln!("wrote {path}");
                0
            }
            Err(e) => {
                eprintln!("write {path} failed: {e}");
                1
            }
        },
        None => {
            println!("{doc}");
            0
        }
    }
}

/// Set by the process signal handler; a watcher thread forwards it to the
/// server's stop handle (handlers themselves must stay async-signal-safe,
/// so the handler only flips this flag).
static SIGNAL_STOP: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_stop_signal(_sig: i32) {
    SIGNAL_STOP.store(true, std::sync::atomic::Ordering::Relaxed);
}

/// Route SIGTERM (15) and SIGINT (2) into [`SIGNAL_STOP`]. Declared by
/// hand: libc is unavailable offline, and `signal(2)` is in every libc
/// the target links anyway.
#[cfg(unix)]
fn install_stop_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    unsafe {
        signal(15, on_stop_signal); // SIGTERM
        signal(2, on_stop_signal); // SIGINT
    }
}

#[cfg(not(unix))]
fn install_stop_signal_handlers() {}

fn cmd_serve(flags: &HashMap<String, String>) -> i32 {
    let default_addr = "127.0.0.1:7700".to_string();
    let addr = flags.get("addr").unwrap_or(&default_addr);
    let data_dir = flags.get("data-dir").map(std::path::PathBuf::from);
    let max_graphs = match flags.get("max-graphs").map(|v| v.parse::<usize>()) {
        Some(Ok(0)) => {
            eprintln!("--max-graphs must be at least 1");
            return 2;
        }
        Some(Ok(n)) => Some(n),
        Some(Err(e)) => {
            eprintln!("bad --max-graphs: {e}");
            return 2;
        }
        None => None,
    };
    let ack_mode = match flags.get("ack-mode").map(String::as_str) {
        None => AckMode::Local,
        Some(name) => match AckMode::from_name(name) {
            Some(m) => m,
            None => {
                eprintln!("bad --ack-mode {name} (local|quorum)");
                return 2;
            }
        },
    };
    let replicate_from = flags.get("replicate-from").cloned();
    let snapshot_shards = match flags.get("snapshot-shards").map(|v| v.parse::<usize>()) {
        Some(Ok(0)) => {
            eprintln!("--snapshot-shards must be at least 1");
            return 2;
        }
        Some(Ok(k)) => k,
        Some(Err(e)) => {
            eprintln!("bad --snapshot-shards: {e}");
            return 2;
        }
        None => 1,
    };
    let slow_ms = match flags.get("slow-ms").map(|v| v.parse::<u64>()) {
        Some(Ok(ms)) => Some(ms),
        Some(Err(e)) => {
            eprintln!("bad --slow-ms: {e}");
            return 2;
        }
        None => None,
    };
    let log_level = match flags.get("log-level") {
        None => crate::obs::filter_from_env(),
        Some(v) => match crate::obs::parse_filter(v) {
            Some(f) => f,
            None => {
                eprintln!("bad --log-level {v} (debug|info|warn|error|off)");
                return 2;
            }
        },
    };
    let durable = data_dir.is_some();
    let mut cfg = ServerCfg::new(addr);
    cfg.engine = engine_if_available();
    cfg.data_dir = data_dir;
    cfg.max_graphs = max_graphs;
    cfg.snapshot_shards = snapshot_shards;
    cfg.replicate_from = replicate_from.clone();
    cfg.ack_mode = ack_mode;
    cfg.slow_ms = slow_ms;
    cfg.log_level = log_level;
    if let Some(cap) = flags.get("trace-cap") {
        match cap.parse::<usize>() {
            Ok(n) => cfg.trace_capacity = n,
            Err(e) => {
                eprintln!("bad --trace-cap: {e}");
                return 2;
            }
        }
    }
    match Server::bind_cfg(cfg) {
        Ok(server) => {
            println!("bimatch service listening on {}", server.local_addr().unwrap());
            if durable {
                // recovery already ran inside bind_cfg
                let recovered = server.store().len();
                println!("durability on: {recovered} stored graph(s) recovered from the data dir");
            }
            match &replicate_from {
                Some(primary) => println!(
                    "replica of {primary}: read-only, tailing its WAL stream \
                     (send PROMOTE to fail over)"
                ),
                None => println!("ack mode: {}", ack_mode.name()),
            }
            println!(
                "protocol: MATCH family=<f> n=<n> [seed=..] [permute=0|1] [algo=..] | \
                 LOAD name=<g> family=..|mtx=.. | UPDATE name=<g> [add=r:c,..] [del=r:c,..] \
                 [addcols=r;r|..] [addrows=c;c|..] | MATCH name=<g> | DROP name=<g> | \
                 SAVE name=<g> | ALGOS | GRAPHS | STATS [graph=<g>] | \
                 TRACE [name=<g>] [last=<n>] | METRICS | LAG | HEALTH | DUMP | \
                 PROMOTE | QUIT"
            );
            // SIGTERM/SIGINT → graceful stop: the watcher flips the stop
            // handle, serve() drains in-flight requests and fsyncs WALs
            install_stop_signal_handlers();
            let stop = server.stop_handle();
            std::thread::spawn(move || loop {
                if SIGNAL_STOP.load(std::sync::atomic::Ordering::Relaxed) {
                    stop.store(true, std::sync::atomic::Ordering::Relaxed);
                    return;
                }
                std::thread::sleep(std::time::Duration::from_millis(100));
            });
            if let Err(e) = server.serve() {
                eprintln!("serve error: {e}");
                return 1;
            }
            println!("shutdown: requests drained, WALs synced");
            0
        }
        Err(e) => {
            eprintln!("bind {addr} failed: {e}");
            1
        }
    }
}

/// Offline durability check over a `--data-dir`: read-only, exit 0 when
/// every finding is one crash recovery handles, 1 on any FATAL finding,
/// 2 on usage/IO errors.
fn cmd_fsck(flags: &HashMap<String, String>) -> i32 {
    let Some(dir) = flags.get("data-dir") else {
        eprintln!("fsck requires --data-dir <path>");
        return 2;
    };
    let report = match crate::sanitize::fsck::fsck_dir(std::path::Path::new(dir)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fsck {dir}: {e}");
            return 2;
        }
    };
    println!("fsck {}: {} graph(s) with on-disk state", dir, report.graphs.len());
    for f in &report.findings {
        println!("  [{}] {}: {}", f.severity.name(), f.graph, f.message);
    }
    let (fatal, repairable) = (report.fatal_count(), report.repairable_count());
    if fatal > 0 {
        eprintln!("fsck: {fatal} FATAL finding(s), {repairable} repairable");
        1
    } else {
        println!("fsck: clean ({repairable} repairable finding(s), 0 fatal)");
        0
    }
}

/// Validate one per-bench telemetry document (`bimatch-bench/1` — what
/// `benches/common::Report` writes) and return its bench name.
fn validate_bench_doc(doc: &crate::util::json::Value) -> Result<String, String> {
    use crate::util::json::Value;
    match doc.get("schema").and_then(Value::as_str) {
        Some("bimatch-bench/1") => {}
        other => return Err(format!("schema must be \"bimatch-bench/1\", got {other:?}")),
    }
    let bench = doc
        .get("bench")
        .and_then(Value::as_str)
        .ok_or("missing string field \"bench\"")?
        .to_string();
    doc.get("unix_ms").and_then(Value::as_f64).ok_or("missing numeric field \"unix_ms\"")?;
    let metrics =
        doc.get("metrics").and_then(Value::as_arr).ok_or("missing array field \"metrics\"")?;
    for (i, m) in metrics.iter().enumerate() {
        m.get("name").and_then(Value::as_str).ok_or(format!("metrics[{i}] missing name"))?;
        m.get("value").and_then(Value::as_f64).ok_or(format!("metrics[{i}] missing value"))?;
        m.get("unit").and_then(Value::as_str).ok_or(format!("metrics[{i}] missing unit"))?;
        m.get("higher_is_better")
            .and_then(Value::as_bool)
            .ok_or(format!("metrics[{i}] missing higher_is_better"))?;
    }
    Ok(bench)
}

/// `name → (value, higher_is_better)` for one bench document.
fn metric_map(doc: &crate::util::json::Value) -> std::collections::BTreeMap<String, (f64, bool)> {
    use crate::util::json::Value;
    let mut out = std::collections::BTreeMap::new();
    if let Some(arr) = doc.get("metrics").and_then(Value::as_arr) {
        for m in arr {
            if let (Some(n), Some(v), Some(h)) = (
                m.get("name").and_then(Value::as_str),
                m.get("value").and_then(Value::as_f64),
                m.get("higher_is_better").and_then(Value::as_bool),
            ) {
                out.insert(n.to_string(), (v, h));
            }
        }
    }
    out
}

/// `YYYY-MM-DD` from unix milliseconds (Gregorian civil-from-days; no
/// chrono offline).
fn civil_date(unix_ms: u64) -> String {
    let days = (unix_ms / 86_400_000) as i64;
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if m <= 2 { y + 1 } else { y };
    format!("{y:04}-{m:02}-{d:02}")
}

/// Merge `target/bench/*.json` into one `BENCH_<date>.json` report and
/// (optionally) gate against a committed baseline. Exit 0 clean, 1 on a
/// schema violation, no input, or a regression beyond `--max-regress`,
/// 2 on usage errors.
fn cmd_bench_report(flags: &HashMap<String, String>) -> i32 {
    use crate::util::json::{self, Value};
    use std::collections::BTreeMap;
    let default_dir = "target/bench".to_string();
    let dir = flags.get("dir").unwrap_or(&default_dir);
    let max_regress = match flags.get("max-regress").map(|v| v.parse::<f64>()) {
        None => 0.20,
        Some(Ok(f)) if f > 0.0 => f,
        Some(other) => {
            eprintln!("bad --max-regress {other:?} (positive fraction, e.g. 0.20)");
            return 2;
        }
    };
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("bench-report: cannot read {dir}: {e} (run the benches first)");
            return 2;
        }
    };
    let mut paths: Vec<std::path::PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    let mut benches: BTreeMap<String, Value> = BTreeMap::new();
    for path in &paths {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("bench-report: read {}: {e}", path.display());
                return 1;
            }
        };
        let doc = match json::parse(&text) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bench-report: {} is not valid JSON: {e}", path.display());
                return 1;
            }
        };
        match validate_bench_doc(&doc) {
            Ok(bench) => {
                println!("  {} ← {}", bench, path.display());
                benches.insert(bench, doc);
            }
            Err(e) => {
                eprintln!("bench-report: {} violates bimatch-bench/1: {e}", path.display());
                return 1;
            }
        }
    }
    if benches.is_empty() {
        eprintln!("bench-report: no *.json telemetry under {dir} (run the benches first)");
        return 1;
    }
    let now_ms = crate::trace::unix_ms();
    let mut top = BTreeMap::new();
    top.insert("schema".into(), Value::Str("bimatch-bench-report/1".into()));
    top.insert("generated_unix_ms".into(), Value::Num(now_ms as f64));
    top.insert("git".into(), Value::Str(env!("BIMATCH_GIT_HASH").into()));
    top.insert("benches".into(), Value::Obj(benches.clone()));
    let report = Value::Obj(top);
    let out = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("BENCH_{}.json", civil_date(now_ms)));
    if let Err(e) = std::fs::write(&out, report.to_json() + "\n") {
        eprintln!("bench-report: write {out}: {e}");
        return 1;
    }
    println!("bench-report: merged {} bench(es) → {out}", benches.len());
    let Some(baseline_path) = flags.get("baseline") else { return 0 };
    let baseline = match std::fs::read_to_string(baseline_path)
        .map_err(|e| e.to_string())
        .and_then(|t| json::parse(&t))
    {
        Ok(b) => b,
        Err(e) => {
            eprintln!("bench-report: baseline {baseline_path}: {e}");
            return 2;
        }
    };
    let Some(base_benches) = baseline.get("benches").and_then(Value::as_obj) else {
        eprintln!("bench-report: baseline {baseline_path} has no \"benches\" object");
        return 2;
    };
    let mut regressions = Vec::new();
    for (name, old_doc) in base_benches {
        let Some(new_doc) = benches.get(name) else {
            println!("  {name}: in baseline but not in this run (skipped)");
            continue;
        };
        // only compare like with like: a smoke-sized run against a
        // full-sized baseline would gate on nothing but the size change
        let smoke = |d: &Value| d.get("smoke").and_then(Value::as_bool);
        if smoke(old_doc) != smoke(new_doc) {
            println!("  {name}: smoke mode differs from baseline (skipped)");
            continue;
        }
        let old_m = metric_map(old_doc);
        let new_m = metric_map(new_doc);
        for (metric, (old_v, hib)) in &old_m {
            let Some((new_v, _)) = new_m.get(metric) else { continue };
            if *old_v <= 0.0 {
                continue;
            }
            let regressed = if *hib {
                *new_v < old_v * (1.0 - max_regress)
            } else {
                *new_v > old_v * (1.0 + max_regress)
            };
            if regressed {
                regressions.push(format!(
                    "{name}/{metric}: {new_v:.3} vs baseline {old_v:.3} \
                     ({}, allowed ±{:.0}%)",
                    if *hib { "higher is better" } else { "lower is better" },
                    max_regress * 100.0
                ));
            }
        }
    }
    if regressions.is_empty() {
        println!("bench-report: no metric regressed beyond {:.0}%", max_regress * 100.0);
        0
    } else {
        for r in &regressions {
            eprintln!("REGRESSION {r}");
        }
        eprintln!("bench-report: {} regression(s) vs {baseline_path}", regressions.len());
        1
    }
}

fn cmd_artifacts_check() -> i32 {
    match Engine::open_default() {
        Ok(engine) => {
            println!("PJRT platform: {}", engine.platform());
            let names: Vec<String> =
                engine.manifest().artifacts.iter().map(|a| a.name.clone()).collect();
            for name in names {
                match engine.load(&name) {
                    Ok(e) => println!("  {} ({}x{} k={}) compiled OK", name, e.meta.nc, e.meta.nr, e.meta.k),
                    Err(err) => {
                        eprintln!("  {name}: FAILED: {err:#}");
                        return 1;
                    }
                }
            }
            0
        }
        Err(e) => {
            eprintln!("artifacts unavailable: {e:#}\nrun `make artifacts` first");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn parse_flags_mixed() {
        let args: Vec<String> = ["--family", "kron", "--n", "100", "--permute", "pos"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let (map, positional) = parse_flags(&args);
        assert_eq!(map.get("family").unwrap(), "kron");
        assert_eq!(map.get("n").unwrap(), "100");
        assert_eq!(map.get("permute").unwrap(), "1");
        assert_eq!(positional, vec!["pos"]);
    }

    #[test]
    fn run_command_end_to_end() {
        let code = cmd_run(&flags(&[("family", "uniform"), ("n", "300"), ("algo", "hk")]));
        assert_eq!(code, 0);
    }

    #[test]
    fn run_command_bad_family() {
        assert_eq!(cmd_run(&flags(&[("family", "bogus"), ("n", "10")])), 2);
    }

    #[test]
    fn run_command_frontier_compacted() {
        // default algo rewritten to the -FC twin and executed end-to-end
        let code = cmd_run(&flags(&[
            ("family", "banded"),
            ("n", "400"),
            ("frontier", "compacted"),
        ]));
        assert_eq!(code, 0);
        // explicit gpu algo picks up the suffix too
        let code = cmd_run(&flags(&[
            ("family", "uniform"),
            ("n", "300"),
            ("algo", "gpu:APsB-GPUBFS-CT"),
            ("frontier", "compacted"),
        ]));
        assert_eq!(code, 0);
        // the "gpu" registry alias works with --frontier
        let code = cmd_run(&flags(&[
            ("family", "uniform"),
            ("n", "300"),
            ("algo", "gpu"),
            ("frontier", "compacted"),
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn run_command_frontier_fullscan_keeps_cpu_routing() {
        // the frontier override rides on the routed pick: a graph the
        // router sends to pfp/dfs stays there; only GPU picks get their
        // "-FC" suffix normalized (exercised in coordinator::exec tests)
        let code = cmd_run(&flags(&[
            ("family", "uniform"),
            ("n", "300"),
            ("frontier", "fullscan"),
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn run_command_frontier_rejects_bad_inputs() {
        assert_eq!(
            cmd_run(&flags(&[("family", "uniform"), ("n", "100"), ("frontier", "warp")])),
            2
        );
        // --frontier (either mode) only makes sense for gpu:* algorithms
        for mode in ["compacted", "fullscan"] {
            assert_eq!(
                cmd_run(&flags(&[
                    ("family", "uniform"),
                    ("n", "100"),
                    ("algo", "hk"),
                    ("frontier", mode),
                ])),
                2
            );
        }
    }

    #[test]
    fn run_command_frontier_fullscan_strips_fc_suffix() {
        // explicit fullscan overrides an -FC algo name instead of being a
        // silent no-op
        let code = cmd_run(&flags(&[
            ("family", "uniform"),
            ("n", "300"),
            ("algo", "gpu:APFB-GPUBFS-WR-CT-FC"),
            ("frontier", "fullscan"),
        ]));
        assert_eq!(code, 0);
    }

    #[test]
    fn run_command_timeout_ms() {
        // generous deadline: normal completion
        let code = cmd_run(&flags(&[("family", "uniform"), ("n", "200"), ("timeout-ms", "60000")]));
        assert_eq!(code, 0);
        // zero deadline: the run trips at its first checkpoint and the
        // CLI reports the distinct timeout failure
        let code = cmd_run(&flags(&[("family", "uniform"), ("n", "200"), ("timeout-ms", "0")]));
        assert_eq!(code, 1);
        // malformed value rejected before any work
        assert_eq!(
            cmd_run(&flags(&[("family", "uniform"), ("n", "100"), ("timeout-ms", "soon")])),
            2
        );
    }

    #[test]
    fn run_command_rejects_malformed_algo() {
        assert_eq!(
            cmd_run(&flags(&[("family", "uniform"), ("n", "100"), ("algo", "gpu:NOPE-FC")])),
            2
        );
        assert_eq!(
            cmd_run(&flags(&[("family", "uniform"), ("n", "100"), ("algo", "p-hk@0")])),
            2
        );
    }

    #[test]
    fn gen_verify_roundtrip() {
        let dir = std::env::temp_dir().join("bimatch_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.mtx");
        let code = cmd_gen(&flags(&[
            ("family", "banded"),
            ("n", "300"),
            ("seed", "5"),
            ("out", path.to_str().unwrap()),
        ]));
        assert_eq!(code, 0);
        let code = cmd_verify(&flags(&[("mtx", path.to_str().unwrap())]));
        assert_eq!(code, 0);
    }

    #[test]
    fn profile_command_writes_chrome_trace_json() {
        let dir = std::env::temp_dir().join("bimatch_cli_profile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let code = cmd_profile(&flags(&[
            ("family", "road"),
            ("n", "800"),
            ("seed", "3"),
            ("algo", "gpu:APFB-GPUBFS-WR-CT-FC"),
            ("out", path.to_str().unwrap()),
        ]));
        assert_eq!(code, 0);
        let doc = std::fs::read_to_string(&path).unwrap();
        assert!(doc.starts_with("{\"traceEvents\":["), "{}", &doc[..80.min(doc.len())]);
        assert!(doc.trim_end().ends_with('}'), "truncated document");
        // both trace processes are named, and kernel spans made it in
        assert!(doc.contains("process_name"), "missing metadata events");
        assert!(doc.contains("modeled cycles"), "missing device process");
        assert!(doc.contains("\"cat\":\"kernel\""), "missing kernel spans");
        assert!(doc.contains("\"cat\":\"phase\""), "missing phase spans");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn profile_command_rejects_bad_inputs() {
        assert_eq!(cmd_profile(&flags(&[("family", "nope"), ("n", "100")])), 2);
        assert_eq!(
            cmd_profile(&flags(&[("family", "uniform"), ("n", "100"), ("algo", "wat")])),
            2
        );
    }

    #[test]
    fn fsck_command_usage_and_clean_dir() {
        assert_eq!(cmd_fsck(&flags(&[])), 2);
        let dir = std::env::temp_dir().join("bimatch_cli_fsck_test");
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(cmd_fsck(&flags(&[("data-dir", dir.to_str().unwrap())])), 0);
        let missing = dir.join("nope");
        let _ = std::fs::remove_dir_all(&missing);
        assert_eq!(cmd_fsck(&flags(&[("data-dir", missing.to_str().unwrap())])), 2);
    }

    #[test]
    fn unknown_command_usage() {
        assert_eq!(main_with_args(vec!["wat".into()]), 2);
        assert_eq!(main_with_args(vec![]), 2);
    }

    #[test]
    fn civil_date_matches_known_days() {
        assert_eq!(civil_date(0), "1970-01-01");
        assert_eq!(civil_date(86_400_000), "1970-01-02");
        // 2000-03-01 (leap-century boundary): 11017 days
        assert_eq!(civil_date(11_017 * 86_400_000), "2000-03-01");
        // 2026-08-07: 20672 days
        assert_eq!(civil_date(20_672 * 86_400_000), "2026-08-07");
    }

    fn bench_doc(bench: &str, value: f64, hib: bool) -> String {
        format!(
            "{{\"schema\":\"bimatch-bench/1\",\"bench\":\"{bench}\",\"unix_ms\":123,\
             \"smoke\":true,\"git\":\"abc\",\"metrics\":[{{\"name\":\"ops\",\
             \"value\":{value},\"unit\":\"ops/s\",\"higher_is_better\":{hib}}}]}}"
        )
    }

    #[test]
    fn bench_report_merges_and_gates() {
        let dir = std::env::temp_dir().join(format!(
            "bimatch_cli_benchreport_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let bench_dir = dir.join("bench");
        std::fs::create_dir_all(&bench_dir).unwrap();
        std::fs::write(bench_dir.join("a.json"), bench_doc("bench_a", 100.0, true)).unwrap();
        std::fs::write(bench_dir.join("b.json"), bench_doc("bench_b", 5.0, false)).unwrap();
        let out = dir.join("BENCH_test.json");
        let base = |d: &str| {
            flags(&[
                ("dir", bench_dir.to_str().unwrap()),
                ("out", out.to_str().unwrap()),
                ("baseline", d),
            ])
        };
        // merge without a baseline
        assert_eq!(
            cmd_bench_report(&flags(&[
                ("dir", bench_dir.to_str().unwrap()),
                ("out", out.to_str().unwrap()),
            ])),
            0
        );
        let report = crate::util::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(
            report.get("schema").and_then(crate::util::json::Value::as_str),
            Some("bimatch-bench-report/1")
        );
        let merged = report.get("benches").and_then(crate::util::json::Value::as_obj).unwrap();
        assert_eq!(merged.len(), 2, "both benches merged");
        assert!(merged.contains_key("bench_a") && merged.contains_key("bench_b"));
        // identical baseline: clean gate
        let baseline = dir.join("baseline.json");
        std::fs::copy(&out, &baseline).unwrap();
        assert_eq!(cmd_bench_report(&base(baseline.to_str().unwrap())), 0);
        // regress bench_a (higher_is_better drops 50% > 20% allowance)
        std::fs::write(bench_dir.join("a.json"), bench_doc("bench_a", 50.0, true)).unwrap();
        assert_eq!(cmd_bench_report(&base(baseline.to_str().unwrap())), 1);
        // within the allowance passes
        std::fs::write(bench_dir.join("a.json"), bench_doc("bench_a", 90.0, true)).unwrap();
        assert_eq!(cmd_bench_report(&base(baseline.to_str().unwrap())), 0);
        // lower_is_better regresses upward
        std::fs::write(bench_dir.join("b.json"), bench_doc("bench_b", 50.0, false)).unwrap();
        assert_eq!(cmd_bench_report(&base(baseline.to_str().unwrap())), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_report_rejects_schema_violations() {
        let dir = std::env::temp_dir().join(format!(
            "bimatch_cli_benchschema_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let args = |d: &std::path::Path| flags(&[("dir", d.to_str().unwrap())]);
        // empty dir: nothing to merge
        assert_eq!(cmd_bench_report(&args(&dir)), 1);
        // wrong schema string
        std::fs::write(dir.join("x.json"), "{\"schema\":\"other/9\",\"bench\":\"x\"}").unwrap();
        assert_eq!(cmd_bench_report(&args(&dir)), 1);
        // malformed JSON
        std::fs::write(dir.join("x.json"), "{not json").unwrap();
        assert_eq!(cmd_bench_report(&args(&dir)), 1);
        // metrics entry missing a key
        std::fs::write(
            dir.join("x.json"),
            "{\"schema\":\"bimatch-bench/1\",\"bench\":\"x\",\"unix_ms\":1,\
             \"metrics\":[{\"name\":\"m\",\"value\":2}]}",
        )
        .unwrap();
        assert_eq!(cmd_bench_report(&args(&dir)), 1);
        // missing dir is a usage error
        assert_eq!(cmd_bench_report(&args(&dir.join("nope"))), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_rejects_bad_log_level() {
        // flag validation happens before any bind
        let code = cmd_serve(&flags(&[("log-level", "loud")]));
        assert_eq!(code, 2);
    }
}
