//! `bimatch` binary entrypoint; all logic lives in [`bimatch::cli`].

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(bimatch::cli::main_with_args(args));
}
