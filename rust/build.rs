//! Stamps the build with the git revision so `HEALTH` replies and the
//! Prometheus `bimatch_build_info` gauge can identify the running
//! binary. Offline-safe: a missing `git` (or a non-repo checkout)
//! degrades to "unknown" instead of failing the build.

use std::process::Command;

fn main() {
    let hash = Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string());
    println!("cargo:rustc-env=BIMATCH_GIT_HASH={hash}");
    // re-stamp when HEAD moves (best-effort: the file may not exist in
    // a tarball checkout, and that's fine)
    println!("cargo:rerun-if-changed=../.git/HEAD");
}
