"""L2 correctness: the full JAX APFB/APsB matcher against
scipy.sparse.csgraph.maximum_bipartite_matching (an independent
Hopcroft–Karp), over hypothesis-generated graphs and structured cases."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import maximum_bipartite_matching

from compile import model


def make_ell(rng, nc, nr, k):
    adj = np.full((nc, k), -1, np.int32)
    edges = set()
    for c in range(nc):
        deg = rng.integers(0, min(k, nr) + 1)
        if deg:
            rows = np.sort(rng.choice(nr, size=deg, replace=False))
            adj[c, :deg] = rows
            for r in rows:
                edges.add((int(r), c))
    return adj, edges


def scipy_opt(edges, nr, nc):
    if not edges:
        return 0
    rows = [e[0] for e in edges]
    cols = [e[1] for e in edges]
    m = csr_matrix((np.ones(len(rows)), (rows, cols)), shape=(nr, nc))
    return int((maximum_bipartite_matching(m, perm_type="column") >= 0).sum())


def check_valid(rm, cm, edges):
    for c in range(len(cm)):
        if cm[c] >= 0:
            assert rm[cm[c]] == c, f"col {c} inconsistent"
            assert (int(cm[c]), c) in edges, f"({cm[c]},{c}) not an edge"
        else:
            assert cm[c] == -1
    for r in range(len(rm)):
        if rm[r] >= 0:
            assert cm[rm[r]] == r, f"row {r} inconsistent"
        else:
            assert rm[r] == -1, f"row {r} leftover sentinel {rm[r]}"


def run_model(adj, nr, use_pallas=True, shortest=False, init=None):
    nc = adj.shape[0]
    rmatch = np.full(nr, -1, np.int32)
    cmatch = np.full(nc, -1, np.int32)
    if init is not None:
        for r, c in init:
            rmatch[r] = c
            cmatch[c] = r
    rm, cm, phases, launches = model.apfb_full(
        jnp.array(adj), jnp.array(rmatch), jnp.array(cmatch),
        use_pallas=use_pallas, shortest=shortest,
    )
    return np.asarray(rm), np.asarray(cm), int(phases), int(launches)


@settings(max_examples=40, deadline=None)
@given(
    nc=st.integers(1, 32),
    nr=st.integers(1, 32),
    k=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_apfb_optimal_vs_scipy(nc, nr, k, seed):
    rng = np.random.default_rng(seed)
    adj, edges = make_ell(rng, nc, nr, k)
    rm, cm, _, _ = run_model(adj, nr)
    check_valid(rm, cm, edges)
    assert (cm >= 0).sum() == scipy_opt(edges, nr, nc)


@settings(max_examples=15, deadline=None)
@given(
    nc=st.integers(2, 24),
    nr=st.integers(2, 24),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_apsb_optimal_vs_scipy(nc, nr, k, seed):
    """shortest=True is Algorithm 1 verbatim (APsB)."""
    rng = np.random.default_rng(seed)
    adj, edges = make_ell(rng, nc, nr, k)
    rm, cm, _, _ = run_model(adj, nr, shortest=True)
    check_valid(rm, cm, edges)
    assert (cm >= 0).sum() == scipy_opt(edges, nr, nc)


@settings(max_examples=15, deadline=None)
@given(
    nc=st.integers(2, 24),
    nr=st.integers(2, 24),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_apfb_with_greedy_init(nc, nr, k, seed):
    """Starting from a cheap greedy matching must give the same optimum."""
    rng = np.random.default_rng(seed)
    adj, edges = make_ell(rng, nc, nr, k)
    # greedy init
    rmatch = np.full(nr, -1, np.int32)
    init = []
    for c in range(nc):
        for r in adj[c]:
            if r >= 0 and rmatch[r] == -1:
                rmatch[r] = c
                init.append((int(r), c))
                break
    rm, cm, _, _ = run_model(adj, nr, init=init)
    check_valid(rm, cm, edges)
    assert (cm >= 0).sum() == scipy_opt(edges, nr, nc)


def test_pallas_and_ref_paths_agree():
    rng = np.random.default_rng(7)
    adj, edges = make_ell(rng, 40, 40, 4)
    rm1, cm1, p1, l1 = run_model(adj, 40, use_pallas=True)
    rm2, cm2, p2, l2 = run_model(adj, 40, use_pallas=False)
    np.testing.assert_array_equal(rm1, rm2)
    np.testing.assert_array_equal(cm1, cm2)
    assert (p1, l1) == (p2, l2)


def test_perfect_matching_planted():
    n = 64
    rng = np.random.default_rng(3)
    perm = rng.permutation(n)
    k = 4
    adj = np.full((n, k), -1, np.int32)
    for c in range(n):
        extras = rng.choice(n, size=k - 1, replace=False)
        rows = np.unique(np.concatenate([[perm[c]], extras]))[:k]
        adj[c, : len(rows)] = np.sort(rows)
    rm, cm, _, _ = run_model(adj, n)
    assert (cm >= 0).sum() == n


def test_empty_and_star():
    # no edges at all
    adj = np.full((5, 2), -1, np.int32)
    rm, cm, phases, _ = run_model(adj, 5)
    assert (cm >= 0).sum() == 0
    # star: every column adjacent to the single row
    adj = np.zeros((6, 1), np.int32)
    rm, cm, _, _ = run_model(adj, 1)
    assert (cm >= 0).sum() == 1


def test_phase_and_launch_counters_populated():
    rng = np.random.default_rng(11)
    adj, _ = make_ell(rng, 32, 32, 3)
    _, _, phases, launches = run_model(adj, 32)
    assert phases >= 1
    assert launches >= phases  # at least one BFS launch per phase


@pytest.mark.parametrize("nc,nr", [(8, 32), (32, 8), (1, 16), (16, 1)])
def test_rectangular_shapes(nc, nr):
    rng = np.random.default_rng(nc * 100 + nr)
    adj, edges = make_ell(rng, nc, nr, 3)
    rm, cm, _, _ = run_model(adj, nr)
    check_valid(rm, cm, edges)
    assert (cm >= 0).sum() == scipy_opt(edges, nr, nc)
