"""L1 correctness: the Pallas bfs_level kernel against the pure-jnp oracle
(kernels/ref.py), swept over shapes, densities, matching states, and levels
with hypothesis."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    L0,
    bfs_level_ref,
    fixmatching_ref,
    init_bfs_array_ref,
)
from compile.kernels.bfs_level import bfs_level


def random_instance(rng, nc, nr, k, match_frac=0.0, endpoint_frac=0.0):
    """A random ELL graph plus a consistent partial matching state."""
    adj = np.full((nc, k), -1, np.int32)
    for c in range(nc):
        deg = rng.integers(0, min(k, nr) + 1)
        if deg:
            rows = np.sort(rng.choice(nr, size=deg, replace=False))
            adj[c, :deg] = rows
    rmatch = np.full(nr, -1, np.int32)
    cmatch = np.full(nc, -1, np.int32)
    # random consistent matching over existing edges
    for c in rng.permutation(nc):
        if rng.random() < match_frac:
            rows = adj[c][adj[c] >= 0]
            rows = [r for r in rows if rmatch[r] == -1]
            if rows:
                r = int(rng.choice(rows))
                rmatch[r] = c
                cmatch[c] = r
    # sprinkle endpoint sentinels on some free rows (mid-phase state)
    for r in range(nr):
        if rmatch[r] == -1 and rng.random() < endpoint_frac:
            rmatch[r] = -2
    return adj, rmatch, cmatch


def run_both(adj, bfs, rmatch, pred, level, block_cols=256):
    ref = bfs_level_ref(
        jnp.array(adj), jnp.array(bfs), jnp.array(rmatch), jnp.array(pred),
        jnp.int32(level),
    )
    pal = bfs_level(
        jnp.array(adj), jnp.array(bfs), jnp.array(rmatch), jnp.array(pred),
        jnp.int32(level), block_cols=block_cols,
    )
    return ref, pal


def assert_same(ref, pal):
    names = ["bfs_array", "rmatch", "predecessor", "vertex_inserted", "aug_found"]
    for a, b, n in zip(ref, pal, names):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=n)


@settings(max_examples=60, deadline=None)
@given(
    nc=st.integers(1, 48),
    nr=st.integers(1, 48),
    k=st.integers(1, 6),
    match_frac=st.floats(0.0, 1.0),
    endpoint_frac=st.floats(0.0, 0.4),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref_first_level(nc, nr, k, match_frac, endpoint_frac, seed):
    rng = np.random.default_rng(seed)
    adj, rmatch, cmatch = random_instance(rng, nc, nr, k, match_frac, endpoint_frac)
    bfs = np.asarray(init_bfs_array_ref(jnp.array(cmatch)))
    pred = np.full(nr, -1, np.int32)
    ref, pal = run_both(adj, bfs, rmatch, pred, L0)
    assert_same(ref, pal)


@settings(max_examples=20, deadline=None)
@given(
    nc=st.integers(4, 32),
    nr=st.integers(4, 32),
    k=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref_across_levels(nc, nr, k, seed):
    """Run a whole phase level by level, comparing after every launch."""
    rng = np.random.default_rng(seed)
    adj, rmatch, cmatch = random_instance(rng, nc, nr, k, match_frac=0.6)
    bfs_r = jnp.array(np.asarray(init_bfs_array_ref(jnp.array(cmatch))))
    bfs_p = bfs_r
    rm_r = rm_p = jnp.array(rmatch)
    pred_r = pred_p = jnp.full((nr,), -1, jnp.int32)
    for level in range(L0, L0 + nc + 2):
        ref = bfs_level_ref(jnp.array(adj), bfs_r, rm_r, pred_r, jnp.int32(level))
        pal = bfs_level(jnp.array(adj), bfs_p, rm_p, pred_p, jnp.int32(level))
        assert_same(ref, pal)
        bfs_r, rm_r, pred_r, vi, _ = ref
        bfs_p, rm_p, pred_p, _, _ = pal
        if not bool(vi):
            break


@pytest.mark.parametrize("block_cols", [1, 2, 8, 64, 256])
def test_block_size_invariance(block_cols):
    """The tile size is a performance knob — results must be identical."""
    rng = np.random.default_rng(1234)
    adj, rmatch, cmatch = random_instance(rng, 64, 64, 4, match_frac=0.5)
    bfs = np.asarray(init_bfs_array_ref(jnp.array(cmatch)))
    pred = np.full(64, -1, np.int32)
    ref, pal = run_both(adj, bfs, rmatch, pred, L0, block_cols=block_cols)
    assert_same(ref, pal)


def test_empty_graph():
    adj = np.full((4, 2), -1, np.int32)
    rmatch = np.full(3, -1, np.int32)
    bfs = np.full(4, L0, np.int32)
    pred = np.full(3, -1, np.int32)
    ref, pal = run_both(adj, bfs, rmatch, pred, L0)
    assert_same(ref, pal)
    assert not bool(ref[3]) and not bool(ref[4])


def test_min_col_wins_determinism():
    """Two frontier columns adjacent to the same free row: the smaller
    column id must claim it (the chosen serialization)."""
    adj = np.array([[0], [0]], np.int32)  # c0 and c1 both adjacent to r0
    rmatch = np.array([-1], np.int32)
    bfs = np.array([L0, L0], np.int32)
    pred = np.array([-1], np.int32)
    ref, pal = run_both(adj, bfs, rmatch, pred, L0)
    assert_same(ref, pal)
    assert np.asarray(ref[2])[0] == 0  # predecessor = min col
    assert np.asarray(ref[1])[0] == -2


def test_visited_columns_not_reclaimed():
    """A matched column already at a BFS level must not be claimed again."""
    # c0 free -> r0 matched to c1 (bfs_array[c1] visited already)
    adj = np.array([[0], [-1]], np.int32)
    rmatch = np.array([1], np.int32)
    bfs = np.array([L0, L0 + 1], np.int32)  # c1 already claimed
    pred = np.array([-1], np.int32)
    ref, pal = run_both(adj, bfs, rmatch, pred, L0)
    assert_same(ref, pal)
    assert np.asarray(ref[0])[1] == L0 + 1  # unchanged
    assert not bool(ref[3])


@settings(max_examples=40, deadline=None)
@given(
    nr=st.integers(1, 40),
    nc=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_fixmatching_keeps_only_consistent_pairs(nr, nc, seed):
    rng = np.random.default_rng(seed)
    rmatch = rng.integers(-2, nc, size=nr).astype(np.int32)
    cmatch = rng.integers(-2, nr, size=nc).astype(np.int32)
    cmatch[cmatch == -2] = -1  # cmatch never carries the -2 sentinel
    rm, cm = fixmatching_ref(jnp.array(rmatch), jnp.array(cmatch))
    rm, cm = np.asarray(rm), np.asarray(cm)
    for r in range(nr):
        if rm[r] >= 0:
            assert cm[rm[r]] == r
        else:
            assert rm[r] == -1
    for c in range(nc):
        if cm[c] >= 0:
            assert rm[cm[c]] == c
        else:
            assert cm[c] == -1
    # every pair that was consistent beforehand survives
    for r in range(nr):
        c = rmatch[r]
        if c >= 0 and cmatch[c] == r:
            assert rm[r] == c and cm[c] == r
