"""AOT path: lowering to HLO text must succeed, be parseable, execute on
the CPU PJRT client from Python (the same client the Rust runtime wraps),
and agree with the eager model."""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


def test_bfs_level_lowering_has_static_io():
    lowered = aot.lower_bfs_level(64, 32, 4)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "s32[64,4]" in text  # adj parameter shape survives lowering


def test_apfb_lowering_contains_loops():
    lowered = aot.lower_apfb_full(32, 32, 4)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "while" in text  # the matching loop lowered to HLO while


def test_hlo_text_roundtrips_through_parser():
    """The text must be re-parseable by the XLA HLO parser — this is the
    exact property the Rust loader (HloModuleProto::from_text_file) relies
    on."""
    lowered = aot.lower_bfs_level(32, 32, 4)
    text = aot.to_hlo_text(lowered)
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_compiled_artifact_matches_eager():
    """Compile the apfb_full HLO on the CPU PJRT backend and compare with
    the eager jit result on the same inputs."""
    nc = nr = 32
    k = 4
    lowered = aot.lower_apfb_full(nc, nr, k)
    compiled = lowered.compile()
    rng = np.random.default_rng(5)
    adj = np.full((nc, k), -1, np.int32)
    for c in range(nc):
        deg = rng.integers(0, k + 1)
        if deg:
            adj[c, :deg] = np.sort(rng.choice(nr, size=deg, replace=False))
    rmatch = np.full(nr, -1, np.int32)
    cmatch = np.full(nc, -1, np.int32)
    got = compiled(jnp.array(adj), jnp.array(rmatch), jnp.array(cmatch))
    want = model.apfb_full(jnp.array(adj), jnp.array(rmatch), jnp.array(cmatch))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--buckets", "64x64x4"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"bfs_level_64x64x4", "apfb_full_64x64x4"}
    for a in manifest["artifacts"]:
        p = out / a["file"]
        assert p.exists() and p.stat().st_size == a["bytes"]


def test_bucket_parser():
    assert aot.parse_buckets("1024x1024x8") == [(1024, 1024, 8)]
    assert aot.parse_buckets("1x2x3, 4x5x6") == [(1, 2, 3), (4, 5, 6)]
    with pytest.raises(ValueError):
        aot.parse_buckets("nope")
