"""L2: the paper's full GPU matching algorithm (APFB / APsB) as a single
JAX program — `lax.while_loop`s around the L1 Pallas level kernel, the
lockstep ALTERNATE, and FIXMATCHING — so the *entire* matching phase AOT-
lowers to one HLO module that the Rust runtime executes with Python gone.

Determinism: every CUDA race is resolved min-index (see kernels/ref.py);
ALTERNATE advances all augmenting paths in exact lockstep, so per phase the
shallowest path of every BFS tree completes (the progress argument in
DESIGN.md §6), bounding the outer loop by NC+2 phases.

Conventions as everywhere: rmatch/cmatch with -1 free, -2 endpoint
sentinel; bfs_array levels starting at L0 = 2.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import bfs_level as bfs_level_mod
from .kernels.ref import L0, bfs_level_ref, fixmatching_ref, init_bfs_array_ref


def _bfs_phase(adj, rmatch, cmatch, use_pallas, shortest):
    """One combined-BFS phase from all unmatched columns.

    Returns (rmatch', predecessor, aug_found, launches)."""
    nc, _ = adj.shape
    nr = rmatch.shape[0]
    bfs_array = init_bfs_array_ref(cmatch)
    predecessor = jnp.full((nr,), -1, dtype=jnp.int32)

    step = bfs_level_mod.bfs_level if use_pallas else bfs_level_ref

    def cond(state):
        _, _, _, _, vi, aug, level = state
        go = vi
        if shortest:  # APsB: stop at the first level with a path
            go = go & jnp.logical_not(aug)
        # level bound: alternating BFS depth can't exceed nc+2
        return go & (level < L0 + nc + 2)

    def body(state):
        bfs, rm, pred, launches, _, aug, level = state
        bfs2, rm2, pred2, vi2, aug2 = step(adj, bfs, rm, pred, level)
        return (bfs2, rm2, pred2, launches + 1, vi2, aug | aug2, level + 1)

    init = (
        bfs_array,
        rmatch,
        predecessor,
        jnp.int32(0),
        jnp.bool_(True),
        jnp.bool_(False),
        jnp.int32(L0),
    )
    # do-while: run the first level unconditionally via init vi=True
    _, rm, pred, launches, _, aug, _ = lax.while_loop(cond, body, init)
    return rm, pred, aug, launches


def _alternate_lockstep(rmatch, cmatch, predecessor):
    """ALTERNATE (Algorithm 3) with all endpoint threads advancing in exact
    lockstep; column-claim races resolved min-row. Returns (rmatch',
    cmatch')."""
    nr = rmatch.shape[0]
    nc = cmatch.shape[0]
    inf_row = jnp.int32(nr)

    # one logical thread per endpoint row
    row_ids = jnp.arange(nr, dtype=jnp.int32)
    row_vertex = jnp.where(rmatch == -2, row_ids, jnp.int32(-1))

    def cond(state):
        rv, _, _, it = state
        return jnp.any(rv >= 0) & (it < nr + nc + 2)

    def body(state):
        rv, rm, cm, it = state
        active0 = rv >= 0
        rv_safe = jnp.where(active0, rv, 0)
        mc = predecessor[rv_safe]  # matched_col, line 6
        active1 = active0 & (mc >= 0)
        mc_safe = jnp.where(active1, mc, 0)
        mr = cm[mc_safe]  # matched_row, line 7
        mr_safe = jnp.clip(mr, 0, nr - 1)
        # line 8: column already claimed by another alternation
        stop = (mr > -1) & (predecessor[mr_safe] == mc)
        act = active1 & ~stop

        # writes (lines 10-11): all active lanes write rmatch; the column
        # write is won by the minimum row_vertex (one legal serialization)
        rm2 = rm.at[jnp.where(act, rv_safe, nr)].set(
            jnp.where(act, mc, 0), mode="drop"
        )
        col_winner = (
            jnp.full((nc + 1,), inf_row, dtype=jnp.int32)
            .at[jnp.where(act, mc_safe, nc)]
            .min(jnp.where(act, rv_safe, inf_row))
        )[:nc]
        cm2 = jnp.where(col_winner < inf_row, col_winner, cm)

        # line 12: advance (root reached when mr == -1)
        rv2 = jnp.where(act & (mr != -1), mr, jnp.int32(-1))
        return (rv2, rm2, cm2, it + 1)

    _, rm, cm, _ = lax.while_loop(
        cond, body, (row_vertex, rmatch, cmatch, jnp.int32(0))
    )
    return rm, cm


def _matching_phase_loop(adj, rmatch, cmatch, use_pallas, shortest):
    """The outer Algorithm-1 loop. Returns (rmatch, cmatch, phases,
    launches)."""
    nc, _ = adj.shape

    def cond(state):
        _, _, aug, phases, _ = state
        return aug & (phases < nc + 2)

    def body(state):
        rm, cm, _, phases, launches = state
        rm1, pred, aug, l1 = _bfs_phase(adj, rm, cm, use_pallas, shortest)
        rm2, cm2 = _alternate_lockstep(rm1, cm, pred)
        rm3, cm3 = fixmatching_ref(rm2, cm2)
        return (rm3, cm3, aug, phases + 1, launches + l1)

    rm, cm, _, phases, launches = lax.while_loop(
        cond,
        body,
        (rmatch, cmatch, jnp.bool_(True), jnp.int32(0), jnp.int32(0)),
    )
    return rm, cm, phases, launches


@functools.partial(jax.jit, static_argnames=("use_pallas", "shortest"))
def apfb_full(adj, rmatch, cmatch, use_pallas=True, shortest=False):
    """APFB (shortest=False) / APsB (shortest=True) end to end.

    Args:
      adj:    (NC, K) int32 ELL adjacency, -1 padding, K >= max col degree.
      rmatch: (NR,) int32 initial matching (e.g. from the cheap heuristic).
      cmatch: (NC,) int32.

    Returns:
      (rmatch, cmatch, phases, bfs_launches) — a *maximum* matching.
    """
    return _matching_phase_loop(adj, rmatch, cmatch, use_pallas, shortest)


def cardinality(cmatch):
    return jnp.sum(cmatch >= 0)
