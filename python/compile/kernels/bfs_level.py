"""L1: the GPUBFS level-expansion hot spot as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA kernel's
thread↔column mapping becomes a **tile of columns in VMEM** — the grid
iterates column blocks of size ``BC``; each grid step loads its ``(BC,)``
slice of ``bfs_array`` and its dense ``(BC, K)`` ELL neighbor block (the
TPU analogue of coalesced loads), gathers ``rmatch``/``bfs_array`` for the
neighbors (the random-access part the C2050 did through L2), and emits
per-edge *messages*:

    target row (or NR for dead slots) , claiming column (or NC)

The cross-block scatter-min that picks one winning column per row — the
serialization of the CUDA write race — runs as an XLA segment-min outside
the kernel ([`bfs_level`]), where the TPU compiler handles it natively.
All shapes static; ``interpret=True`` everywhere (the CPU PJRT plugin
cannot run Mosaic custom-calls).

VMEM budget per grid step: BC·4 (bfs slice) + BC·K·4 (adj block)
+ NR·4 + NC·4 (full match/level arrays) bytes — e.g. 4096² bucket with
K=16, BC=256: 256·4 + 16 KiB + 2·16 KiB ≈ 50 KiB, far under the ~16 MiB
VMEM of a TPU core; the block size could grow 64× before pressure.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import L0

DEFAULT_BLOCK_COLS = 256


def _bfs_gather_kernel(level_ref, bfs_blk_ref, adj_blk_ref, bfs_full_ref,
                       rmatch_ref, out_row_ref, out_col_ref):
    """One grid step = one column tile.

    Inputs:
      level_ref:   (1,)    current BFS level (SMEM-like scalar input)
      bfs_blk_ref: (BC,)   bfs_array slice for this tile
      adj_blk_ref: (BC,K)  ELL rows for this tile (-1 pad)
      bfs_full_ref:(NC,)   full bfs_array (for the col_match visited test)
      rmatch_ref:  (NR,)   full rmatch
    Outputs (this tile's message block):
      out_row_ref: (BC,K)  target row, NR where no message
      out_col_ref: (BC,K)  claiming column (global id), NC where none
    """
    level = level_ref[0]
    bc, k = adj_blk_ref.shape
    nc = bfs_full_ref.shape[0]
    nr = rmatch_ref.shape[0]
    blk = pl.program_id(0)

    bfs_blk = bfs_blk_ref[...]
    adj = adj_blk_ref[...]
    rmatch = rmatch_ref[...]
    bfs_full = bfs_full_ref[...]

    active = bfs_blk == level  # (BC,)
    valid = (adj >= 0) & active[:, None]  # (BC,K)
    safe_rows = jnp.where(valid, adj, 0)
    col_match = rmatch[safe_rows]  # gather (BC,K)
    # a message is useful iff the row is free (endpoint) or its matched
    # column is still unvisited — the kernel pre-filters so the global
    # reduction only sees live edges (this is the win over doing it all
    # in XLA: the gather + filter runs tile-local in VMEM)
    cm_safe = jnp.where(col_match >= 0, col_match, 0)
    useful = valid & (
        (col_match == -1) | ((col_match >= 0) & (bfs_full[cm_safe] == L0 - 1))
    )
    global_cols = (
        blk * bc + jax.lax.broadcasted_iota(jnp.int32, (bc, k), 0)
    )
    out_row_ref[...] = jnp.where(useful, adj, nr).astype(jnp.int32)
    out_col_ref[...] = jnp.where(useful, global_cols, nc).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_cols",))
def bfs_level(adj, bfs_array, rmatch, predecessor, level,
              block_cols=DEFAULT_BLOCK_COLS):
    """GPUBFS level expansion: Pallas gather/filter kernel + XLA scatter-min.

    Same signature/semantics as `ref.bfs_level_ref` (min-col serialization).
    NC must be a multiple of `block_cols` (the AOT buckets guarantee it).
    """
    nc, k = adj.shape
    nr = rmatch.shape[0]
    # shrink the tile until it divides NC (buckets are powers of two, so
    # this only triggers for small ad-hoc shapes in tests)
    while nc % block_cols != 0:
        block_cols //= 2
    grid = nc // block_cols

    level_arr = jnp.asarray(level, dtype=jnp.int32).reshape((1,))
    out_shape = (
        jax.ShapeDtypeStruct((nc, k), jnp.int32),
        jax.ShapeDtypeStruct((nc, k), jnp.int32),
    )
    msg_rows, msg_cols = pl.pallas_call(
        _bfs_gather_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                # level
            pl.BlockSpec((block_cols,), lambda i: (i,)),       # bfs slice
            pl.BlockSpec((block_cols, k), lambda i: (i, 0)),   # adj tile
            pl.BlockSpec((nc,), lambda i: (0,)),               # bfs full
            pl.BlockSpec((nr,), lambda i: (0,)),               # rmatch
        ],
        out_specs=(
            pl.BlockSpec((block_cols, k), lambda i: (i, 0)),
            pl.BlockSpec((block_cols, k), lambda i: (i, 0)),
        ),
        out_shape=out_shape,
        interpret=True,
    )(level_arr, bfs_array, adj, bfs_array, rmatch)

    # ---- global winner selection (XLA scatter-min) ----
    inf_col = jnp.int32(nc)
    winner = (
        jnp.full((nr + 1,), inf_col, dtype=jnp.int32)
        .at[msg_rows.ravel()]
        .min(msg_cols.ravel())
    )[:nr]
    reached = winner < inf_col

    col_match = jnp.where(reached, rmatch, jnp.int32(-3))
    is_endpoint = col_match == -1
    is_matched = col_match >= 0
    # the kernel already filtered visited columns, so every matched message
    # row claims its column
    bfs_next = bfs_array.at[jnp.where(is_matched, col_match, nc)].set(
        jnp.asarray(level, jnp.int32) + 1, mode="drop"
    )
    pred_next = jnp.where(is_endpoint | is_matched, winner, predecessor)
    rmatch_next = jnp.where(is_endpoint, jnp.int32(-2), rmatch)
    vertex_inserted = jnp.any(is_matched)
    aug_found = jnp.any(is_endpoint)
    return bfs_next, rmatch_next, pred_next, vertex_inserted, aug_found
