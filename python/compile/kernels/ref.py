"""Pure-jnp oracle for the L1 kernels.

Deterministic, race-free reformulation of the paper's GPUBFS level kernel
(Algorithm 2) over an ELL-packed adjacency:

* the CUDA race "several frontier columns claim the same row" is resolved
  by the **minimum column index** (one of the legal serializations of the
  hardware race — see DESIGN.md §Hardware-Adaptation);
* all shapes are static: ``adj`` is ``(NC, K)`` int32 with ``-1`` padding,
  ``K >= max column degree``.

Conventions (identical to the paper / rust side):
  rmatch[r] = c (matched), -1 (free), -2 (augmenting-path endpoint)
  bfs_array[c] = L0-1 (matched, unvisited), L0 (BFS root), level+1 (claimed)
"""

import jax.numpy as jnp

L0 = 2  # BFS start level; live levels stay positive (paper §3)


def bfs_level_ref(adj, bfs_array, rmatch, predecessor, level):
    """One GPUBFS level expansion; the min-col-wins serialization.

    Args:
      adj:        (NC, K) int32, row ids, -1 padding.
      bfs_array:  (NC,)   int32.
      rmatch:     (NR,)   int32.
      predecessor:(NR,)   int32.
      level:      scalar  int32, current BFS level.

    Returns:
      (bfs_array', rmatch', predecessor', vertex_inserted, aug_found)
    """
    nc, k = adj.shape
    nr = rmatch.shape[0]
    inf_col = jnp.int32(nc)  # > any real column id

    active = bfs_array == level  # (NC,)
    valid = (adj >= 0) & active[:, None]  # (NC, K)
    # rows with an already-found endpoint (-2) are not re-claimed; free (-1)
    # and matched (>=0) rows are both candidates at this stage.
    safe_rows = jnp.where(valid, adj, nr).astype(jnp.int32)  # pad -> NR slot
    col_ids = jnp.broadcast_to(
        jnp.arange(nc, dtype=jnp.int32)[:, None], (nc, k)
    )
    cand_cols = jnp.where(valid, col_ids, inf_col)

    # winner column per row: scatter-min into an (NR+1,) buffer
    winner = (
        jnp.full((nr + 1,), inf_col, dtype=jnp.int32)
        .at[safe_rows.ravel()]
        .min(cand_cols.ravel())
    )[:nr]
    reached = winner < inf_col  # (NR,)

    col_match = jnp.where(reached, rmatch, jnp.int32(-3))  # -3 = untouched
    is_endpoint = col_match == -1
    is_matched = col_match >= 0
    cm_idx = jnp.where(is_matched, col_match, 0)
    unvisited = is_matched & (bfs_array[cm_idx] == L0 - 1)

    # claim the matched columns of newly-reached rows
    bfs_next = bfs_array.at[jnp.where(unvisited, col_match, nc)].set(
        level + 1, mode="drop"
    )
    pred_next = jnp.where(is_endpoint | unvisited, winner, predecessor)
    rmatch_next = jnp.where(is_endpoint, jnp.int32(-2), rmatch)

    vertex_inserted = jnp.any(unvisited)
    aug_found = jnp.any(is_endpoint)
    return bfs_next, rmatch_next, pred_next, vertex_inserted, aug_found


def init_bfs_array_ref(cmatch):
    """INITBFSARRAY: L0-1 for matched columns, L0 for unmatched."""
    return jnp.where(cmatch > -1, jnp.int32(L0 - 1), jnp.int32(L0))


def fixmatching_ref(rmatch, cmatch):
    """FIXMATCHING: clear -2 sentinels and dangling pointers (both sides),
    keeping exactly the mutually-consistent pairs."""
    nr = rmatch.shape[0]
    nc = cmatch.shape[0]
    r_ids = jnp.arange(nr, dtype=jnp.int32)
    c_ids = jnp.arange(nc, dtype=jnp.int32)
    r_ok = (rmatch >= 0) & (cmatch[jnp.clip(rmatch, 0, nc - 1)] == r_ids)
    rmatch_f = jnp.where(r_ok, rmatch, jnp.int32(-1))
    c_ok = (cmatch >= 0) & (rmatch_f[jnp.clip(cmatch, 0, nr - 1)] == c_ids)
    cmatch_f = jnp.where(c_ok, cmatch, jnp.int32(-1))
    return rmatch_f, cmatch_f
