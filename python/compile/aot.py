"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for the Rust
PJRT runtime.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per (NC, NR, K) bucket:
  bfs_level_{NC}x{NR}x{K}.hlo.txt  — one GPUBFS level expansion
  apfb_full_{NC}x{NR}x{K}.hlo.txt  — the whole APFB matching loop
plus ``manifest.json`` describing every artifact (shapes, inputs, outputs)
for ``runtime::artifacts`` discovery on the Rust side.

Usage: python -m compile.aot --out-dir ../artifacts [--buckets 1024x1024x8,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import bfs_level as bfs_level_mod

DEFAULT_BUCKETS = [(1024, 1024, 8), (4096, 4096, 16)]


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned by the text
    parser, sidestepping the 64-bit-id proto incompatibility)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bfs_level(nc, nr, k):
    adj = jax.ShapeDtypeStruct((nc, k), jnp.int32)
    vec_c = jax.ShapeDtypeStruct((nc,), jnp.int32)
    vec_r = jax.ShapeDtypeStruct((nr,), jnp.int32)
    level = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(adj, bfs_array, rmatch, predecessor, level):
        bfs2, rm2, pred2, vi, aug = bfs_level_mod.bfs_level(
            adj, bfs_array, rmatch, predecessor, level
        )
        return (
            bfs2,
            rm2,
            pred2,
            vi.astype(jnp.int32),
            aug.astype(jnp.int32),
        )

    return jax.jit(fn).lower(adj, vec_c, vec_r, vec_r, level)


def lower_apfb_full(nc, nr, k, use_pallas=True):
    adj = jax.ShapeDtypeStruct((nc, k), jnp.int32)
    vec_c = jax.ShapeDtypeStruct((nc,), jnp.int32)
    vec_r = jax.ShapeDtypeStruct((nr,), jnp.int32)

    def fn(adj, rmatch, cmatch):
        rm, cm, phases, launches = model.apfb_full(
            adj, rmatch, cmatch, use_pallas=use_pallas, shortest=False
        )
        return rm, cm, phases, launches

    return jax.jit(fn).lower(adj, vec_r, vec_c)


def parse_buckets(spec: str):
    out = []
    for part in spec.split(","):
        nc, nr, k = (int(x) for x in part.strip().split("x"))
        out.append((nc, nr, k))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--buckets",
        default=",".join(f"{a}x{b}x{c}" for a, b, c in DEFAULT_BUCKETS),
        help="comma-separated NCxNRxK bucket shapes",
    )
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the pure-jnp reference instead of the Pallas kernel "
        "(debugging aid)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "l0": 2, "artifacts": []}
    for nc, nr, k in parse_buckets(args.buckets):
        for kind, lowered in (
            ("bfs_level", lower_bfs_level(nc, nr, k)),
            ("apfb_full", lower_apfb_full(nc, nr, k, use_pallas=not args.no_pallas)),
        ):
            name = f"{kind}_{nc}x{nr}x{k}"
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "kind": kind,
                    "file": f"{name}.hlo.txt",
                    "nc": nc,
                    "nr": nr,
                    "k": k,
                    "bytes": len(text),
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
